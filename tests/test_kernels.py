"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(0)


def _randn(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 3e-5, jnp.bfloat16: 4e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,d,window", [
    (2, 256, 4, 2, 64, None),
    (1, 256, 4, 4, 64, 128),
    (2, 384, 6, 2, 64, None),
    (1, 512, 8, 1, 32, 256),
])
def test_flash_attention_sweep(b, s, h, hkv, d, window, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = _randn((b, s, h, d), dtype)
    k = _randn((b, s, hkv, d), dtype)
    v = _randn((b, s, hkv, d), dtype)
    o = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    r = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_matches_model_layer_math():
    """Kernel semantics == the model's attention (same masking rules)."""
    from repro.kernels.flash_attention.ref import flash_attention_ref
    from repro.models.layers import attention_full
    b, s, h, hkv, d = 2, 128, 4, 2, 32
    q = _randn((b, s, h, d), jnp.float32)
    k = _randn((b, s, hkv, d), jnp.float32)
    v = _randn((b, s, hkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o1 = attention_full(q, k, v, pos, pos, causal=True)
    o2 = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention + gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hkv,g,d,npages,page,p", [
    (3, 2, 4, 64, 16, 8, 4),
    (2, 1, 8, 32, 8, 16, 3),
    (1, 4, 1, 128, 32, 8, 8),
])
def test_paged_decode_attention_sweep(b, hkv, g, d, npages, page, p, dtype):
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    q = _randn((b, hkv, g, d), dtype)
    kp = _randn((npages, page, hkv, d), dtype)
    vp = _randn((npages, page, hkv, d), dtype)
    tbl = RNG.permutation(npages)[: b * p].reshape(b, p).astype(np.int32)
    tbl[0, -1] = -1  # a hole (non-resident block)
    lens = np.minimum(RNG.integers(1, p * page, b), p * page).astype(np.int32)
    o = paged_decode_attention(q, kp, vp, jnp.asarray(tbl),
                               jnp.asarray(lens), interpret=True)
    r = paged_decode_attention_ref(q, kp, vp, jnp.asarray(tbl),
                                   jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_medic_gather(dtype):
    from repro.kernels.medic_gather.ops import medic_gather
    from repro.kernels.medic_gather.ref import medic_gather_ref
    pool = _randn((12, 8, 2, 32), dtype)
    tbl = jnp.asarray([[0, 5, -1], [3, -1, 11]], jnp.int32)
    o = medic_gather(pool, tbl, interpret=True)
    r = medic_gather_ref(pool, tbl)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(r))


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,w,bt,bw", [
    (2, 64, 256, 16, 128),
    (1, 128, 128, 32, 64),
    (3, 48, 384, 16, 128),
])
def test_rg_lru_sweep(b, s, w, bt, bw):
    from repro.kernels.rg_lru.ops import rg_lru
    from repro.kernels.rg_lru.ref import rg_lru_ref
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (b, s, w)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((b, s, w)) * 0.1, jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((b, w)), jnp.float32)
    o = rg_lru(a, x, h0, bw=bw, bt=bt, interpret=True)
    r = rg_lru_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5,
                               rtol=1e-5)


def test_rg_lru_matches_model_scan():
    from repro.kernels.rg_lru.ref import rg_lru_ref
    from repro.models.recurrent import rglru_scan
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (2, 32, 64)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((2, 32, 64)), jnp.float32)
    r1 = rg_lru_ref(a, b, jnp.zeros((2, 64)))
    r2 = rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (2, 128, 2, 32, 64, 32),
    (1, 64, 4, 16, 32, 16),
    (2, 96, 1, 64, 64, 32),
])
def test_mlstm_kernel_sweep(b, s, h, dk, dv, chunk):
    from repro.kernels.mlstm.ops import mlstm
    from repro.kernels.mlstm.ref import mlstm_ref
    q = _randn((b, s, h, dk), jnp.float32)
    k = _randn((b, s, h, dk), jnp.float32)
    v = _randn((b, s, h, dv), jnp.float32)
    li = _randn((b, s, h), jnp.float32)
    lf = jnp.log(jax.nn.sigmoid(_randn((b, s, h), jnp.float32) + 2))
    o = mlstm(q, k, v, li, lf, chunk=chunk, interpret=True)
    r = mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=5e-4,
                               rtol=5e-3)


def test_mlstm_chunkwise_matches_recurrent():
    """Model chunkwise form == exact recurrent form (state carrying)."""
    from repro.models.xlstm import mlstm_chunkwise, mlstm_recurrent_ref
    b, s, h, dk, dv = 2, 128, 2, 16, 32
    q = _randn((b, s, h, dk), jnp.float32)
    k = _randn((b, s, h, dk), jnp.float32)
    v = _randn((b, s, h, dv), jnp.float32)
    li = _randn((b, s, h), jnp.float32)
    lf = jnp.log(jax.nn.sigmoid(_randn((b, s, h), jnp.float32) + 2))
    o1, st1 = mlstm_chunkwise(q, k, v, li, lf, chunk=32)
    o2, st2 = mlstm_recurrent_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-4,
                               rtol=5e-3)
    np.testing.assert_allclose(np.asarray(st1[0]), np.asarray(st2[0]),
                               atol=5e-4, rtol=5e-3)
