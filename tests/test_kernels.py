"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(0)


def _randn(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 3e-5, jnp.bfloat16: 4e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,d,window", [
    (2, 256, 4, 2, 64, None),
    (1, 256, 4, 4, 64, 128),
    (2, 384, 6, 2, 64, None),
    (1, 512, 8, 1, 32, 256),
])
def test_flash_attention_sweep(b, s, h, hkv, d, window, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = _randn((b, s, h, d), dtype)
    k = _randn((b, s, hkv, d), dtype)
    v = _randn((b, s, hkv, d), dtype)
    o = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    r = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_matches_model_layer_math():
    """Kernel semantics == the model's attention (same masking rules)."""
    from repro.kernels.flash_attention.ref import flash_attention_ref
    from repro.models.layers import attention_full
    b, s, h, hkv, d = 2, 128, 4, 2, 32
    q = _randn((b, s, h, d), jnp.float32)
    k = _randn((b, s, hkv, d), jnp.float32)
    v = _randn((b, s, hkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o1 = attention_full(q, k, v, pos, pos, causal=True)
    o2 = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# paged decode attention + gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hkv,g,d,npages,page,p", [
    (3, 2, 4, 64, 16, 8, 4),
    (2, 1, 8, 32, 8, 16, 3),
    (1, 4, 1, 128, 32, 8, 8),
])
def test_paged_decode_attention_sweep(b, hkv, g, d, npages, page, p, dtype):
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    q = _randn((b, hkv, g, d), dtype)
    kp = _randn((npages, page, hkv, d), dtype)
    vp = _randn((npages, page, hkv, d), dtype)
    tbl = RNG.permutation(npages)[: b * p].reshape(b, p).astype(np.int32)
    tbl[0, -1] = -1  # a hole (non-resident block)
    lens = np.minimum(RNG.integers(1, p * page, b), p * page).astype(np.int32)
    o = paged_decode_attention(q, kp, vp, jnp.asarray(tbl),
                               jnp.asarray(lens), interpret=True)
    r = paged_decode_attention_ref(q, kp, vp, jnp.asarray(tbl),
                                   jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_medic_gather(dtype):
    from repro.kernels.medic_gather.ops import medic_gather
    from repro.kernels.medic_gather.ref import medic_gather_ref
    pool = _randn((12, 8, 2, 32), dtype)
    tbl = jnp.asarray([[0, 5, -1], [3, -1, 11]], jnp.int32)
    o = medic_gather(pool, tbl, interpret=True)
    r = medic_gather_ref(pool, tbl)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(r))


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,w,bt,bw", [
    (2, 64, 256, 16, 128),
    (1, 128, 128, 32, 64),
    (3, 48, 384, 16, 128),
])
def test_rg_lru_sweep(b, s, w, bt, bw):
    from repro.kernels.rg_lru.ops import rg_lru
    from repro.kernels.rg_lru.ref import rg_lru_ref
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (b, s, w)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((b, s, w)) * 0.1, jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((b, w)), jnp.float32)
    o = rg_lru(a, x, h0, bw=bw, bt=bt, interpret=True)
    r = rg_lru_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5,
                               rtol=1e-5)


def test_rg_lru_matches_model_scan():
    from repro.kernels.rg_lru.ref import rg_lru_ref
    from repro.models.recurrent import rglru_scan
    a = jnp.asarray(RNG.uniform(0.8, 0.999, (2, 32, 64)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((2, 32, 64)), jnp.float32)
    r1 = rg_lru_ref(a, b, jnp.zeros((2, 64)))
    r2 = rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,dk,dv,chunk", [
    (2, 128, 2, 32, 64, 32),
    (1, 64, 4, 16, 32, 16),
    (2, 96, 1, 64, 64, 32),
])
def test_mlstm_kernel_sweep(b, s, h, dk, dv, chunk):
    from repro.kernels.mlstm.ops import mlstm
    from repro.kernels.mlstm.ref import mlstm_ref
    q = _randn((b, s, h, dk), jnp.float32)
    k = _randn((b, s, h, dk), jnp.float32)
    v = _randn((b, s, h, dv), jnp.float32)
    li = _randn((b, s, h), jnp.float32)
    lf = jnp.log(jax.nn.sigmoid(_randn((b, s, h), jnp.float32) + 2))
    o = mlstm(q, k, v, li, lf, chunk=chunk, interpret=True)
    r = mlstm_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=5e-4,
                               rtol=5e-3)


def test_mlstm_chunkwise_matches_recurrent():
    """Model chunkwise form == exact recurrent form (state carrying)."""
    from repro.models.xlstm import mlstm_chunkwise, mlstm_recurrent_ref
    b, s, h, dk, dv = 2, 128, 2, 16, 32
    q = _randn((b, s, h, dk), jnp.float32)
    k = _randn((b, s, h, dk), jnp.float32)
    v = _randn((b, s, h, dv), jnp.float32)
    li = _randn((b, s, h), jnp.float32)
    lf = jnp.log(jax.nn.sigmoid(_randn((b, s, h), jnp.float32) + 2))
    o1, st1 = mlstm_chunkwise(q, k, v, li, lf, chunk=32)
    o2, st2 = mlstm_recurrent_ref(q, k, v, li, lf)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-4,
                               rtol=5e-3)
    np.testing.assert_allclose(np.asarray(st1[0]), np.asarray(st2[0]),
                               atol=5e-4, rtol=5e-3)


# ---------------------------------------------------------------------------
# wavefront segmented queue recovery
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as hyp_st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_WS_KW = dict(banks=8, channels=4, l2_svc=4.0, l2_lat=20.0,
              occ_rowhit=4.0, occ_rowmiss=10.0)


def _wave_case(rng, n, dyadic=True, empty=False, banks=8, channels=4,
               warm_carry=True):
    """One fuzzed wave: sorted arrivals, random queue membership, random
    cross-wave carry (some queues never-touched: -inf anchors)."""
    step = 0.25 if dyadic else 0.7
    t_s = jnp.asarray(np.cumsum(rng.integers(0, 4, n)) * step, jnp.float32)
    bank = jnp.asarray(rng.integers(0, banks, n), jnp.int32)
    ch = jnp.asarray(rng.integers(0, channels, n), jnp.int32)
    row = jnp.asarray(rng.integers(0, 6, n), jnp.int32)
    if empty:
        valid = np.zeros(n, bool)
    else:
        valid = rng.random(n) < 0.9
    byp = (rng.random(n) < 0.2) & valid
    hit = (rng.random(n) < 0.4) & valid & ~byp
    use_l2 = jnp.asarray(valid & ~byp)
    go_dram = jnp.asarray(valid & (byp | ~hit))
    hp = jnp.asarray(rng.random(n) < 0.5)

    def qvec(q, lo, hi):
        return jnp.asarray(rng.uniform(lo, hi, q) * (4 if dyadic else 1),
                           jnp.float32)
    neg = jnp.asarray(np.where(rng.random(channels) < 0.3, -np.inf, 0.0),
                      jnp.float32)
    negb = jnp.asarray(np.where(rng.random(banks) < 0.3, -np.inf, 0.0),
                       jnp.float32)
    if not warm_carry:
        negb = jnp.full((banks,), -jnp.inf)
        neg = jnp.full((channels,), -jnp.inf)
    from repro.kernels.wavefront_scan.ref import QueueCarry
    carry = QueueCarry(
        bank_free=qvec(banks, 0, 30), bank_ts=qvec(banks, 0, 20) + negb,
        hp_free=qvec(channels, 0, 40), hp_ts=qvec(channels, 0, 20) + neg,
        hp_sa=qvec(channels, 0, 20) + neg,
        lp_free=qvec(channels, 0, 40), lp_ts=qvec(channels, 0, 20) + neg,
        lp_sa=qvec(channels, 0, 20) + neg,
        cur_row=jnp.asarray(rng.integers(-1, 6, channels), jnp.int32))
    return (t_s, bank, use_l2, ch, row, go_dram, jnp.asarray(byp), hp,
            carry)


def _recover(args, backend, exact=False, interpret=True):
    from repro.kernels.wavefront_scan.ops import wave_queue_recovery
    return wave_queue_recovery(*args, exact=exact, backend=backend,
                               interpret=interpret, **_WS_KW)


def _assert_wave_equal(a, b, slots_exactly=True, go_dram=None):
    """Compare (t_head, t0, row_hit, carry) across backends. ``t0`` is
    compared only where the contract defines it (``go_dram`` slots)."""
    ta, t0a, rha, ca = a
    tb, t0b, rhb, cb = b
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))
    gd = np.asarray(go_dram) if go_dram is not None else \
        np.ones(np.asarray(t0a).shape, bool)
    np.testing.assert_array_equal(np.asarray(t0a)[gd], np.asarray(t0b)[gd])
    np.testing.assert_array_equal(np.asarray(rha), np.asarray(rhb))
    for f, va, vb in zip(ca._fields, ca, cb):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                      err_msg=f"carry field {f}")


@pytest.mark.parametrize("dyadic", [True, False])
@pytest.mark.parametrize("n", [1, 3, 17, 96, 256, 600])
def test_wavefront_scan_fused_bitwise(n, dyadic):
    """The fused slot-major path is bit-for-bit equal to the unfused
    oracle — including on non-dyadic floats (same elementwise ops on the
    same values; max exactly associative; integer-valued cumsums exact),
    which is what lets the engine default to it under 1e-6 goldens."""
    rng = np.random.default_rng(n * 2 + dyadic)
    args = _wave_case(rng, n, dyadic=dyadic)
    _assert_wave_equal(_recover(args, "ref"), _recover(args, "fused"),
                       go_dram=args[5])


@pytest.mark.parametrize("exact", [True, False])
def test_wavefront_scan_fused_bitwise_exact_mode(exact):
    """Both carry-floor modes (plain busy-until vs backlog interp)."""
    rng = np.random.default_rng(7)
    args = _wave_case(rng, 64, dyadic=False)
    _assert_wave_equal(_recover(args, "ref", exact=exact),
                       _recover(args, "fused", exact=exact),
                       go_dram=args[5])


@pytest.mark.parametrize("n", [1, 5, 96, 256, 600, 1024])
def test_wavefront_scan_pallas_interpret(n):
    """The chunked Pallas kernel (interpret mode on CPU) is exactly
    equal on dyadic inputs — the chunk re-association of the prefix sums
    is exact on integer-valued occupancies — across single- and
    multi-chunk sizes (chunk = 256)."""
    rng = np.random.default_rng(n)
    args = _wave_case(rng, n, dyadic=True)
    _assert_wave_equal(_recover(args, "ref"),
                       _recover(args, "pallas", interpret=True),
                       go_dram=args[5])


def test_wavefront_scan_pallas_nondyadic_close():
    """Non-dyadic inputs: chunk re-association may round differently, so
    the kernel is allclose, not bitwise."""
    rng = np.random.default_rng(11)
    args = _wave_case(rng, 600, dyadic=False)
    tr, t0r, rhr, cr = _recover(args, "ref")
    tp, t0p, rhp, cp = _recover(args, "pallas", interpret=True)
    gd = np.asarray(args[5])
    np.testing.assert_allclose(np.asarray(tr), np.asarray(tp), atol=1e-3)
    np.testing.assert_allclose(np.asarray(t0r)[gd], np.asarray(t0p)[gd],
                               atol=1e-3)
    np.testing.assert_array_equal(np.asarray(rhr), np.asarray(rhp))


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_wavefront_scan_empty_wave(backend):
    """A wave with no valid slot is a no-op: the carry round-trips
    bitwise (this is what makes the engine's early-exit while_loop
    byte-identical to running the dead tail waves)."""
    rng = np.random.default_rng(13)
    args = _wave_case(rng, 48, dyadic=False, empty=True)
    ref = _recover(args, "ref")
    out = _recover(args, backend)
    _assert_wave_equal(ref, out, go_dram=args[5])
    for f, va, vb in zip(ref[3]._fields, args[8], out[3]):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                      err_msg=f"carry field {f} changed "
                                              "on an empty wave")


@pytest.mark.parametrize("backend", ["fused", "pallas"])
def test_wavefront_scan_single_slot(backend):
    """n=1 waves (single-slot: one warp, one lane) across every request
    species: L2-only, DRAM hp, DRAM lp, bypass-direct."""
    from repro.kernels.wavefront_scan.ref import QueueCarry
    rng = np.random.default_rng(17)
    base = _wave_case(rng, 1, dyadic=False)
    for use, go, byp, hp in [(True, False, False, False),
                             (True, True, False, True),
                             (True, True, False, False),
                             (False, True, True, True)]:
        args = (base[0], base[1], jnp.asarray([use]), base[3], base[4],
                jnp.asarray([go]), jnp.asarray([byp]), jnp.asarray([hp]),
                base[8])
        _assert_wave_equal(_recover(args, "ref"), _recover(args, backend),
                           go_dram=args[5])


def test_wavefront_scan_cold_carry():
    """All-virgin queues (-inf anchors, as at t=0) don't poison the
    fused path's gathered floors."""
    rng = np.random.default_rng(23)
    args = _wave_case(rng, 96, dyadic=False, warm_carry=False)
    _assert_wave_equal(_recover(args, "ref"), _recover(args, "fused"),
                       go_dram=args[5])
    _assert_wave_equal(_recover(args, "ref"), _recover(args, "pallas"),
                       go_dram=args[5])


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=hyp_st.integers(1, 300), seed=hyp_st.integers(0, 2**31),
           dyadic=hyp_st.booleans(), empty=hyp_st.booleans())
    def test_wavefront_scan_fused_hypothesis(n, seed, dyadic, empty):
        """Fuzz mask patterns (incl. empty queues / single-slot waves):
        fused stays bitwise-equal to the oracle."""
        rng = np.random.default_rng(seed)
        args = _wave_case(rng, n, dyadic=dyadic, empty=empty)
        _assert_wave_equal(_recover(args, "ref"), _recover(args, "fused"),
                           go_dram=args[5])


# ---------------------------------------------------------------------------
# wavefront cache pass
# ---------------------------------------------------------------------------

def _cache_case(rng, n_warps, b, lanes, prm, pa, addr_hi=60, empty=False):
    """One fuzzed cache-pass wave over a warmed state. The warmed tags
    honor the engine invariant the fused backend relies on: non-(-1)
    tags are unique within a set (a line lives in at most one way —
    allocation only happens on miss)."""
    from repro.core.engine.state import init_state
    from repro.policy import ops as POL
    sets = prm.sets
    st = init_state(n_warps, prm)
    pool = np.argsort(rng.random((sets, 4 * prm.ways + addr_hi)),
                      axis=1)[:, :prm.ways]
    tags_np = np.where(rng.random((sets, prm.ways)) < 0.25, -1, pool)
    st = st._replace(
        tags=jnp.asarray(tags_np, jnp.int32),
        rrip=jnp.asarray(rng.integers(0, prm.rrip_max + 1,
                                      (sets, prm.ways)), jnp.int32),
        meta_type=jnp.asarray(rng.integers(0, 3, (sets, prm.ways)),
                              jnp.int32),
        eaf=jnp.asarray(rng.integers(0, 2, prm.eaf_bits), jnp.int32),
        eaf_ctr=jnp.asarray(rng.integers(0, prm.eaf_capacity), jnp.int32),
        pc_hits=jnp.asarray(rng.integers(0, 50, prm.pc_entries), jnp.int32),
        pc_acc=jnp.asarray(rng.integers(50, 100, prm.pc_entries),
                           jnp.int32),
        pc_req=jnp.asarray(rng.integers(0, 100, prm.pc_entries), jnp.int32))
    st = st._replace(clf=st.clf._replace(
        accesses=jnp.asarray(rng.integers(0, 64, n_warps), jnp.int32),
        hits=jnp.asarray(rng.integers(0, 32, n_warps), jnp.int32),
        sampled=jnp.asarray(rng.integers(0, 64, n_warps), jnp.int32)))
    w_sel = jnp.asarray(rng.choice(n_warps, b, replace=False), jnp.int32)
    clf_b0 = jax.tree.map(lambda a: a[w_sel], st.clf)
    tokens_b = POL.pcal_tokens(pa, n_warps)[w_sel]
    t0 = jnp.sort(jnp.asarray(rng.uniform(0, 50, b), jnp.float32))
    addr_lb = jnp.asarray(rng.integers(-1, addr_hi, (lanes, b)), jnp.int32)
    pc_b = jnp.asarray(rng.integers(0, 64, b), jnp.int32)
    owt_b = jnp.asarray(rng.integers(0, 3, b), jnp.int32)
    slot_ok = jnp.zeros(b, bool) if empty \
        else jnp.asarray(rng.random(b) < 0.9)
    if empty:
        addr_lb = jnp.full_like(addr_lb, -1)
    return st, (clf_b0, tokens_b, t0, addr_lb, pc_b, owt_b, slot_ok)


def _cache_run(st, args, prm, pa, backend, interpret=False):
    from repro.kernels.cache_pass.ops import wave_cache_pass
    return wave_cache_pass(st, *args, prm, pa, backend=backend,
                           interpret=interpret)


def _cache_assert_equal(a, b):
    ra = jax.tree_util.tree_leaves_with_path(a)
    rb = jax.tree_util.tree_leaves_with_path(b)
    for (p, va), (_, vb) in zip(ra, rb):
        np.testing.assert_array_equal(
            np.asarray(va), np.asarray(vb),
            err_msg=f"leaf {jax.tree_util.keystr(p)}")


# (sets, wave width B, lanes, addr_hi): sets=1 collapses EVERY request
# into one set (maximal conflict chains); sets=2 makes every conflict a
# neighbor of the adjacent set's chain; B >= 128 engages the wide-wave
# chronology-pointer construction; the last grid is the sparse regime
# (aliasing only through the hash).
_CACHE_GRIDS = [(1, 8, 16, 40), (2, 8, 16, 40), (4, 12, 5, 30),
                (8, 160, 16, 60), (512, 200, 16, 4000)]


@pytest.mark.parametrize("sets,b,lanes,addr_hi", _CACHE_GRIDS)
def test_cache_pass_fused_bitwise_aliasing_grids(sets, b, lanes, addr_hi):
    """Deterministic worst-case same-set aliasing: the fused sweep's
    last-write-wins conflict resolution must reproduce the sequential
    ref scan bitwise on state, classifier, and records."""
    from repro.core import baselines as BL
    from repro.core.engine.state import SimParams
    from repro.policy import to_arrays
    prm = SimParams(sets=sets)
    rng = np.random.default_rng(sets * 1000 + b)
    for pol in (BL.BASELINE, BL.MEDIC, BL.PCAL, BL.WBYP):
        pa = to_arrays(pol)
        st, args = _cache_case(rng, max(2 * b, b + 1), b, lanes, prm, pa,
                               addr_hi=addr_hi)
        _cache_assert_equal(_cache_run(st, args, prm, pa, "ref"),
                            _cache_run(st, args, prm, pa, "fused"))


def test_cache_pass_fused_bitwise_empty_wave():
    """No valid slot: the pass must be a state no-op, bitwise, in both
    backends (what makes the engine's dead tail waves free)."""
    from repro.core import baselines as BL
    from repro.core.engine.state import SimParams
    from repro.policy import to_arrays
    prm = SimParams(sets=8)
    pa = to_arrays(BL.MEDIC)
    rng = np.random.default_rng(5)
    st, args = _cache_case(rng, 16, 6, 8, prm, pa, empty=True)
    ref = _cache_run(st, args, prm, pa, "ref")
    _cache_assert_equal(ref, _cache_run(st, args, prm, pa, "fused"))
    np.testing.assert_array_equal(np.asarray(ref[0].tags),
                                  np.asarray(st.tags))
    np.testing.assert_array_equal(np.asarray(ref[0].pc_req),
                                  np.asarray(st.pc_req))


def test_cache_pass_pallas_interpret_tiny():
    """The lane-chunked Pallas kernel (interpret mode on CPU) against
    both jnp backends — integer/select arithmetic throughout, so the
    claim is bitwise. ONE tiny case: interpret mode runs the lane grid
    in Python and compiles slowly."""
    from repro.core import baselines as BL
    from repro.core.engine.state import SimParams
    from repro.policy import to_arrays
    prm = SimParams(sets=8, ways=2, eaf_bits=32, eaf_capacity=8,
                    pc_entries=8)
    pa = to_arrays(BL.MEDIC)
    rng = np.random.default_rng(9)
    st, args = _cache_case(rng, 12, 3, 4, prm, pa, addr_hi=40)
    ref = _cache_run(st, args, prm, pa, "ref")
    _cache_assert_equal(ref, _cache_run(st, args, prm, pa, "pallas",
                                        interpret=True))


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=hyp_st.integers(0, 2**31),
           weights=hyp_st.tuples(*([hyp_st.integers(0, 2)] * 4)),
           boost=hyp_st.floats(2.0, 8.0),
           pool=hyp_st.sampled_from([8, 16, 32]))
    def test_cache_pass_fused_hypothesis_aliasing_traces(
            seed, weights, boost, pool):
        """Engine-level fuzz: TraceSpecs engineered so wave members pile
        into few cache sets (tiny set count, small shared pool, boosted
        shared fractions, pool-heavy mixes) must stay fused == ref
        bitwise on every reported metric. Shape is held fixed so every
        example reuses one compiled executable per backend."""
        from repro.core import baselines as BL
        from repro.core import tracegen as TG
        from repro.core.simulator import SimParams as SP, simulate_sweep
        # weight the pool-visiting archetypes; all_miss streams past the
        # pool so it keeps its default weight
        mix = np.asarray((0.0,) + tuple(float(w) for w in weights),
                         np.float64)
        mix[3] += 1.0                          # ensure a pool-heavy floor
        spec = TG.TraceSpec(
            name="alias", mix=tuple(mix / mix.sum()), intensity=0.9,
            n_warps=16, n_instr=10, lines_per_instr=8, n_pcs=6,
            shared_pool_lines=pool, shared_boost=boost)
        tr = TG.generate(spec, seed=seed)
        args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                jnp.asarray(tr["compute_gap"]))
        prm = SP(sets=4)
        outs = {
            be: simulate_sweep(args[0], args[1], args[2],
                               (BL.MEDIC, BL.WBYP), n_warps=16, lanes=8,
                               prm=prm, engine="wavefront",
                               cache_backend=be)
            for be in ("ref", "fused")}
        for k in outs["ref"]:
            assert np.array_equal(np.asarray(outs["ref"][k]),
                                  np.asarray(outs["fused"][k]),
                                  equal_nan=True), k
