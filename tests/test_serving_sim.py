"""Open-loop serving simulator tests: arrival processes, metamorphic
invariants, fast==ref pool differential, and ServeEngine parity.

Three layers, mirroring DESIGN.md §14:

  * arrivals   — deterministic grid over the process family (seed
                 determinism, monotonicity, realized-rate tolerance,
                 burst/diurnal structure) plus a hypothesis fuzz of the
                 ServingSpec space when hypothesis is installed, and a
                 Little's-law sanity check on a long stable Poisson run;
  * simulator  — metamorphic invariants (doubling slots under an ample
                 budget never worsens the tail on the same stream,
                 zero-arrival streams are a no-op, closed-loop admits in
                 request order), the vectorized-vs-sequential pool
                 transaction differential, and the declarative api path
                 (validation, plan bucketing, pool_backend plumbing,
                 the >= 2048-concurrent acceptance run);
  * parity     — the sim replayed on the IDENTICAL generate_requests
                 workload must match ``ServeEngine.run`` per request
                 (enqueue / first-token / finish / stall) and per pool
                 counter, on both pool backends.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core.tracegen.spec import trace_key
from repro.serving.pool import POOL_POLICIES, PoolConfig
from repro.serving.sim import (SERVING_SPECS, ServingSpec, arrival_times,
                               from_requests, generate_serving,
                               simulate_serving)
from repro.serving.sim.arrivals import _unit_poisson

OPEN_PROCESSES = ("poisson", "bursty", "diurnal")


def _spec(process: str, **kw) -> ServingSpec:
    """A small test spec; name depends only on the process so streams
    stay comparable across shape-only changes."""
    base = dict(name=f"T_{process.upper()}", process=process, rate=1.5,
                n_requests=256)
    base.update(kw)
    return ServingSpec(**base)


# -- arrival processes --------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 7])
@pytest.mark.parametrize("process", OPEN_PROCESSES)
def test_arrivals_deterministic_and_monotone(process, seed):
    spec = _spec(process)
    t = arrival_times(spec, seed)
    assert t.shape == (spec.n_requests,) and t.dtype == np.float64
    assert np.all(np.isfinite(t))
    assert np.all(t >= 0.0)
    assert np.all(np.diff(t) >= 0.0)
    # bit-identical on replay, distinct across seeds
    assert np.array_equal(t, arrival_times(spec, seed))
    assert not np.array_equal(t, arrival_times(spec, seed + 101))


def test_closed_process_arrives_at_zero():
    t = arrival_times(_spec("closed"), 3)
    assert np.array_equal(t, np.zeros(256))


@pytest.mark.parametrize("process", OPEN_PROCESSES)
def test_realized_rate_matches_spec(process):
    """Bursty/diurnal warp a unit-rate process through Λ⁻¹, so the MEAN
    rate must stay ``spec.rate`` for every process."""
    spec = _spec(process, n_requests=4096, rate=2.5)
    for seed in (0, 3):
        t = arrival_times(spec, seed)
        assert spec.n_requests / t[-1] == pytest.approx(2.5, rel=0.1)


def test_bursty_concentrates_arrivals_in_bursts():
    """duty=0.25 at boost=3 puts duty*boost = 75% of arrivals inside the
    burst window of each period."""
    spec = _spec("bursty", n_requests=4096, rate=2.0, burst_period=64.0,
                 burst_duty=0.25, burst_boost=3.0)
    phase = np.mod(arrival_times(spec, 0), 64.0)
    assert np.mean(phase <= 16.0) == pytest.approx(0.75, abs=0.05)


def test_diurnal_modulates_arrival_density():
    """The sin>0 half-period carries (1 + 2·amp/π)/2 of the arrivals."""
    spec = _spec("diurnal", n_requests=4096, rate=2.5,
                 diurnal_period=128.0, diurnal_amp=0.8)
    t = arrival_times(spec, 0)
    high = np.sin(2.0 * np.pi * t / 128.0) > 0.0
    assert np.mean(high) == pytest.approx(0.5 + 0.8 / np.pi, abs=0.05)


def test_diurnal_inverse_is_consistent():
    """The bisection inverse really inverts Λ: pushing the returned
    times back through the integrated rate recovers the unit-rate
    event times."""
    spec = _spec("diurnal", n_requests=512, rate=1.7)
    t = arrival_times(spec, 5)
    t_unit = _unit_poisson(trace_key(spec.name, 5), 512)
    w = 2.0 * np.pi / spec.diurnal_period
    lam = spec.rate * (t + spec.diurnal_amp / w * (1.0 - np.cos(w * t)))
    np.testing.assert_allclose(lam, t_unit, rtol=1e-9, atol=1e-6)


def test_generate_serving_population_and_determinism():
    spec = _spec("poisson", chat_frac=0.75)
    a = generate_serving(spec, 0)
    b = generate_serving(spec, 0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    chat = a["prefix_id"] >= 0
    assert np.mean(chat) == pytest.approx(0.75, abs=0.12)
    # class-conditional attribute ranges
    c_lo, c_hi = spec.chat_prompt
    r_lo, r_hi = spec.rag_prompt
    assert np.all((a["prompt_len"][chat] >= c_lo)
                  & (a["prompt_len"][chat] < c_hi))
    assert np.all((a["prompt_len"][~chat] >= r_lo)
                  & (a["prompt_len"][~chat] < r_hi))
    assert np.all(a["prefix_id"][chat] < spec.n_shared_prefixes)
    assert np.all(a["prefix_len"][chat] == spec.shared_prefix_len)
    assert np.all(a["prefix_id"][~chat] == -1)
    assert np.all(a["prefix_len"][~chat] == 0)
    d_lo, d_hi = spec.decode
    assert np.all((a["decode_len"] >= d_lo) & (a["decode_len"] < d_hi))


def test_request_identity_is_prefix_stable():
    """Attributes are sub-streams indexed by request id, so the first k
    requests are identical no matter how many follow them."""
    a = generate_serving(_spec("poisson", n_requests=256), 0)
    b = generate_serving(_spec("poisson", n_requests=64), 0)
    for k in a:
        np.testing.assert_array_equal(a[k][:64], b[k])


# deterministic grid above always runs; hypothesis (when installed — the
# CI image has it) fuzzes the ServingSpec space with the same checker
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(data=st.data())
    def test_arrivals_fuzz_monotone_deterministic(data):
        process = data.draw(st.sampled_from(OPEN_PROCESSES))
        kw = dict(
            rate=data.draw(st.floats(0.05, 20.0)),
            n_requests=data.draw(st.integers(0, 300)),
        )
        if process == "bursty":
            kw["burst_period"] = data.draw(st.floats(8.0, 512.0))
            duty = data.draw(st.floats(0.05, 0.9))
            kw["burst_duty"] = duty
            kw["burst_boost"] = data.draw(st.floats(1.0, 1.0 / duty))
        elif process == "diurnal":
            kw["diurnal_period"] = data.draw(st.floats(8.0, 1024.0))
            kw["diurnal_amp"] = data.draw(st.floats(0.0, 0.95))
        seed = data.draw(st.integers(0, 2**31 - 1))
        spec = _spec(process, **kw)
        t = arrival_times(spec, seed)
        assert t.shape == (spec.n_requests,)
        assert np.all(np.isfinite(t)) and np.all(t >= 0.0)
        assert np.all(np.diff(t) >= 0.0)
        assert np.array_equal(t, arrival_times(spec, seed))


def test_littles_law_on_stable_poisson_run():
    """L = λ·W on a long run-to-completion Poisson stream (discretized
    in-system sampling vs finish−arrival latencies agree to ~3%; 10%
    tolerance leaves slack for the step quantization)."""
    spec = ServingSpec("T_LITTLE", process="poisson", rate=1.0,
                       n_requests=2048, max_slots=64, budget_blocks=4096,
                       fetch_occupancy=0.001, max_steps=8000)
    m = simulate_serving(generate_serving(spec, 0), spec)["metrics"]
    assert m["completed"] == 2048            # stable: nothing truncated
    lam = m["completed"] / m["steps"]
    assert m["mean_in_system"] == pytest.approx(
        lam * m["mean_latency"], rel=0.1)


# -- simulator invariants -----------------------------------------------------


def _ample_spec(slots: int) -> ServingSpec:
    # budget far above demand and negligible transfer occupancy: only
    # queueing for slots changes with max_slots, which is what makes the
    # doubling invariant a theorem rather than a tuning accident
    return ServingSpec("T_SLOTS", process="poisson", rate=1.2,
                       n_requests=256, max_slots=slots, budget_blocks=4096,
                       fetch_occupancy=0.001, max_steps=8000)


@pytest.mark.parametrize("seed", [0, 1])
def test_doubling_slots_never_worsens_tail(seed):
    """Same arrival stream (the spec name keys the RNG, so slot count
    does not perturb it), ample budget: 2x the slots must not increase
    p99 latency."""
    m32 = simulate_serving(generate_serving(_ample_spec(32), seed),
                           _ample_spec(32))["metrics"]
    m64 = simulate_serving(generate_serving(_ample_spec(64), seed),
                           _ample_spec(64))["metrics"]
    assert m32["completed"] == 256 and m64["completed"] == 256
    assert m64["p99_latency"] <= m32["p99_latency"]
    assert m64["p99_queue_wait"] <= m32["p99_queue_wait"]


def test_zero_request_stream_is_a_no_op():
    spec = _spec("poisson", n_requests=0)
    out = simulate_serving(generate_serving(spec, 0), spec)
    m = out["metrics"]
    assert m["steps"] == 0 and m["completed"] == 0 and m["admitted"] == 0
    assert m["tokens_out"] == 0 and m["stall_steps"] == 0
    assert m["fetches"] == 0 and m["evictions"] == 0
    assert np.isnan(m["mean_latency"])


def test_closed_loop_admits_in_request_order():
    """All arrivals at t=0: the stable queue order is request-id order,
    so the first max_slots requests take slots 0..S-1 at step 0 and
    admission steps are non-decreasing in request id."""
    spec = _spec("closed", n_requests=24, max_slots=8)
    out = simulate_serving(generate_serving(spec, 0), spec)
    ra = out["request_arrays"]
    assert np.all(ra["enqueue_step"][:8] == 0)
    assert np.all(np.diff(ra["enqueue_step"]) >= 0)
    assert np.all(ra["finish_step"] >= 0)


@pytest.mark.parametrize("policy", [BL.BASELINE, BL.MEDIC, BL.MEDIC_STALE,
                                    BL.MEDIC_ORACLE],
                         ids=lambda p: p.name)
def test_fast_pool_backend_matches_ref(policy):
    """The vectorized access_batch transaction is bit-identical to the
    sequential per-key reference across the whole labeling ladder."""
    spec = dataclasses.replace(SERVING_SPECS["SERVE_BURSTY64"],
                               n_requests=96, max_steps=1500)
    reqs = generate_serving(spec, 0)
    fast = simulate_serving(reqs, spec, policy=policy, pool_backend="fast")
    ref = simulate_serving(reqs, spec, policy=policy, pool_backend="ref")
    for k, v in fast["request_arrays"].items():
        np.testing.assert_array_equal(v, ref["request_arrays"][k], err_msg=k)
    for k, v in fast["pool"].items():
        np.testing.assert_array_equal(v, ref["pool"][k], err_msg=k)
    np.testing.assert_equal(fast["metrics"], ref["metrics"])


# -- declarative api path -----------------------------------------------------


def test_api_serving_validation():
    from repro import api
    sc = api.Scenario.serving("SERVE_POISSON64")
    with pytest.raises(ValueError, match="need engine='serving'"):
        api.Experiment("bad", (sc,), (BL.MEDIC,), engine="event")
    wc = api.Scenario.workload("BFS")
    with pytest.raises(ValueError, match="only serving scenarios"):
        api.Experiment("bad2", (wc,), (BL.MEDIC,), engine="serving")
    with pytest.raises(ValueError, match="pool_backend"):
        api.Experiment("bad3", (sc,), (BL.MEDIC,), engine="serving",
                       pool_backend="nope")
    with pytest.raises(ValueError, match="unknown serving scenario"):
        api.Scenario.serving("NOPE")
    with pytest.raises(ValueError, match="n_warps"):
        api.Scenario("bad4", SERVING_SPECS["SERVE_POISSON64"], (0,),
                     n_warps=4)


def test_api_serving_plan_buckets_by_shape():
    from repro.api import registry
    exp = registry.get("paper_serving_quick")
    plan = exp.compile()
    # both quick scenarios share (slots=64, requests=192): one bucket
    assert plan.n_calls == 1
    assert "[serving] slots=64 requests=192" in plan.describe()
    full = registry.PAPER_SERVING.compile()
    assert full.n_calls == 2                 # 64-slot bucket + 2k bucket


def test_api_pool_backend_plumbs_through_experiment():
    from repro import api
    spec = dataclasses.replace(SERVING_SPECS["SERVE_POISSON64"],
                               n_requests=64, max_steps=1000)
    sc = api.Scenario.serving(spec)
    fast = api.Experiment("t_fast", (sc,), (BL.MEDIC,), engine="serving")
    ref = fast.with_(name="t_ref", pool_backend="ref")
    rf, rr = fast.run(), ref.run()
    for k in ("completed", "steps", "p99_latency", "stall_steps",
              "fetches", "hit_ratio"):
        assert rf.value(k, policy="MeDiC") == rr.value(k, policy="MeDiC")


def test_api_serving_sustains_2048_in_flight():
    """The acceptance pin: the traffic-scale spec saturates all 2048
    slots concurrently inside one declarative api.Experiment run and
    still completes every request."""
    from repro import api
    sc = api.Scenario.serving("SERVE_POISSON2K")
    rs = api.Experiment("t_2k", (sc,), (BL.MEDIC,), engine="serving").run()
    val = lambda k: rs.value(k, scenario="SERVE_POISSON2K",   # noqa: E731
                             policy="MeDiC", seed=0)
    assert val("max_concurrency") >= 2048
    assert val("completed") == 4096
    assert val("steps") <= 1200


# -- ServeEngine parity -------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_cfg():
    from repro.configs.base import get_config
    return get_config("qwen3_1_7b").reduced(num_layers=2)


@pytest.mark.parametrize("policy_name", ["lru", "medic"])
def test_sim_matches_serve_engine_per_request(tiny_cfg, policy_name):
    """Replay the identical generate_requests workload through the real
    engine and the simulator (both pool backends): per-request lifecycle
    stamps and every pool counter must agree exactly."""
    from repro.serving.engine import EngineConfig, ServeEngine
    from repro.serving.request import ServeWorkload, generate_requests

    wl = ServeWorkload(n_requests=8, arrival_rate=4.0)
    reqs = generate_requests(wl, seed=1)
    pc = PoolConfig(budget_blocks=32, block_tokens=16, policy=policy_name)
    eng = ServeEngine(tiny_cfg, EngineConfig(max_slots=2, max_len=448), pc)
    snap = eng.run(reqs, max_steps=4000)
    assert snap["completed"] == 8            # parity on a finished run

    spec = ServingSpec("T_PARITY", process="closed", n_requests=8,
                       max_slots=2, max_len=448, block_tokens=16,
                       budget_blocks=32, sampling_interval=32,
                       fetch_latency=8.0, fetch_occupancy=1.0,
                       max_steps=4000)
    stream = from_requests(reqs)
    for backend in ("fast", "ref"):
        out = simulate_serving(stream, spec,
                               policy=POOL_POLICIES[policy_name],
                               pool_backend=backend)
        ra = out["request_arrays"]
        assert ra["enqueue_step"].tolist() == \
            [r.enqueue_step for r in reqs], backend
        assert ra["first_token_step"].tolist() == \
            [r.first_token_step for r in reqs], backend
        assert ra["finish_step"].tolist() == \
            [r.finish_step for r in reqs], backend
        assert ra["generated"].tolist() == \
            [r.generated for r in reqs], backend
        assert ra["stall_steps"].tolist() == \
            [r.stall_steps for r in reqs], backend
        pool = out["pool"]
        assert pool["fetches"] == eng.pool.fetches
        assert pool["bypassed_blocks"] == eng.pool.bypassed_blocks
        np.testing.assert_array_equal(pool["hits"], eng.pool.hits)
        np.testing.assert_array_equal(pool["accesses"], eng.pool.accesses)
        np.testing.assert_array_equal(pool["seq_type"], eng.pool.seq_type)
        np.testing.assert_array_equal(pool["evictions_by_type"],
                                      eng.pool.evictions_by_type)
        assert out["metrics"]["steps"] == snap["steps"]
        assert out["metrics"]["completed"] == snap["completed"]
        assert out["metrics"]["tokens_out"] == snap["tokens_out"]
        assert out["metrics"]["stall_steps"] == snap["stall_steps"]
