"""Cross-engine metamorphic test harness (ISSUE 5 satellite).

Where the differential suites pin engine-vs-engine and ref-vs-vectorized
EQUALITY, this suite pins *metamorphic* invariants — transformations of
the inputs whose effect on the outputs is known a priori:

  M1  warp-ID permutation invariance: relabeling warps permutes per-warp
      outputs but leaves AGGREGATE IPC (the sum of per-warp progress
      rates) invariant up to event-interleaving noise, on both engines.
      Holds only for warp-type-driven policies — PCAL's token assignment
      is warp-id-keyed by construction, so it is excluded by design.
  M2  seed-translation determinism: a trace is a pure function of
      (spec, seed) through the counter RNG — regenerating is bit
      identical, batch columns equal singles, distinct seeds differ.
  M3  schedule degeneracy: a single-phase ``phases=[...]`` spec reduces
      BYTE-identically to the legacy static spec (same RNG coordinates).
  M4  engine degeneracy: ``wave_size=1`` makes the wavefront engine the
      event loop — exact on every ``PHASED_*`` spec, oracle and stale
      labeling modes included. (The 1k/2k-warp specs are shrunk to 48
      warps — the full-size event run is the ~10-minute path the
      wavefront engine exists to avoid; the schedule, mixes, churn and
      per-phase intensities are untouched.)

All phased specs exercise drift: these invariants failing only on
phased inputs is exactly the regression class this file exists to
catch.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import tracegen as TG
from repro.core import workloads as WL
from repro.core.simulator import SimParams, simulate_sweep

PRM = SimParams()
#: warp-type-driven policies only (see M1 note on PCAL)
TYPE_POLICIES = (BL.BASELINE, BL.WBYP, BL.MEDIC)

TRACE_KEYS = ("lines", "pcs", "archetype", "archetype2", "oracle_wtype",
              "archetype_phases")


def _shrunk(spec: TG.TraceSpec, n_warps: int = 48) -> TG.TraceSpec:
    return dataclasses.replace(spec, n_warps=min(spec.n_warps, n_warps))


def _sweep(tr, n_warps, lanes, policies, engine, **kw):
    out = simulate_sweep(
        jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
        jnp.asarray(tr["compute_gap"]), policies, n_warps=n_warps,
        lanes=lanes, prm=PRM, engine=engine,
        oracle_types=jnp.asarray(tr["oracle_wtype"]), **kw)
    return {k: np.asarray(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# M1 — warp-ID permutation invariance of aggregate IPC
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source,engine", [
    ("BFS", "event"), ("BFS", "wavefront"),
    ("PHASED48", "event"), ("PHASED48", "wavefront"),
])
def test_warp_permutation_invariance_of_aggregate_ipc(source, engine):
    """Permuting warp ids re-times the event interleaving (tie-breaks,
    wave composition) but must not change aggregate throughput: measured
    worst deviation is 0.7% across engines/specs — asserted at 1.5%."""
    spec = TG.PHASED_SPECS[source] if source in TG.PHASED_SPECS \
        else TG.TraceSpec.from_workload(WL.WORKLOADS[source])
    tr = TG.generate(spec, 0)
    w_n, lanes = spec.n_warps, spec.lines_per_instr
    base = _sweep(tr, w_n, lanes, TYPE_POLICIES, engine)["ipc"]
    perm = np.random.default_rng(1).permutation(w_n)
    tr_p = dict(tr, lines=tr["lines"][:, perm], pcs=tr["pcs"][:, perm],
                oracle_wtype=tr["oracle_wtype"][:, perm])
    permuted = _sweep(tr_p, w_n, lanes, TYPE_POLICIES, engine)["ipc"]
    rel = np.abs(permuted - base) / base
    assert rel.max() <= 0.015, (source, engine, rel)


# ---------------------------------------------------------------------------
# M2 — seed-translation determinism of the counter-RNG stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", TG.PHASED_SPECS)
def test_trace_is_pure_function_of_spec_and_seed(name):
    spec = _shrunk(TG.PHASED_SPECS[name])
    a, b = TG.generate(spec, 7), TG.generate(spec, 7)
    for k in TRACE_KEYS:
        assert np.array_equal(a[k], b[k]), (name, k)
    assert np.array_equal(np.asarray(a["compute_gap"]),
                          np.asarray(b["compute_gap"]))
    # distinct seeds -> distinct streams (same schedule, different draws)
    c = TG.generate(spec, 8)
    assert not np.array_equal(a["lines"], c["lines"]), name


def test_batch_columns_equal_singles_on_phased_specs():
    specs = [_shrunk(TG.PHASED_SPECS[n]) for n in ("PHASED48", "PHASED1K")]
    seeds = (0, 5)
    batch = TG.generate_batch(specs, seeds)
    for ni, spec in enumerate(specs):
        for si, seed in enumerate(seeds):
            one = TG.generate(spec, seed)
            for k in ("lines", "pcs", "archetype", "oracle_wtype"):
                assert np.array_equal(batch[k][ni, si], one[k]), \
                    (spec.name, seed, k)
            np.testing.assert_array_equal(
                batch["compute_gap"][ni, si],
                np.broadcast_to(one["compute_gap"],
                                batch["compute_gap"][ni, si].shape))


# ---------------------------------------------------------------------------
# M3 — a single-phase schedule IS the legacy static spec, byte for byte
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["BFS", "BP", "CONS"])
def test_single_phase_spec_reduces_to_legacy_static(workload, seed=3):
    base = TG.TraceSpec.from_workload(WL.WORKLOADS[workload])
    one = dataclasses.replace(base, phases=(TG.Phase(),))
    a, b = TG.generate(base, seed), TG.generate(one, seed)
    for k in ("lines", "pcs", "archetype", "archetype2", "oracle_wtype"):
        assert np.array_equal(a[k], b[k]), (workload, k)
    # the gap must stay the legacy SCALAR (not a broadcast [I] vector)
    assert np.ndim(b["compute_gap"]) == 0
    assert a["compute_gap"] == b["compute_gap"]
    # and the loop reference agrees on the reduced spec too
    r = TG.generate_ref(one, seed)
    for k in ("lines", "pcs", "oracle_wtype"):
        assert np.array_equal(b[k], r[k]), (workload, k)


def test_single_phase_with_matching_knobs_still_reduces():
    """Explicitly spelling out the defaults (mix=spec.mix, spec
    intensity) must not change a single byte either."""
    base = TG.TraceSpec.from_workload(WL.WORKLOADS["SSSP"])
    one = dataclasses.replace(base, phases=(
        TG.Phase(frac=2.5, mix=base.mix, intensity=base.intensity),))
    a, b = TG.generate(base, 0), TG.generate(one, 0)
    for k in ("lines", "pcs", "archetype", "oracle_wtype"):
        assert np.array_equal(a[k], b[k]), k
    assert np.ndim(b["compute_gap"]) == 0


# ---------------------------------------------------------------------------
# M4 — wave_size=1 wavefront == event, on every PHASED_* spec
# ---------------------------------------------------------------------------

INT_KEYS = ("l2_accesses", "l2_hits", "dram_accesses", "row_hits",
            "bypasses", "qdelay_hist", "evictions_by_type", "warp_type")


@pytest.mark.parametrize("name", TG.PHASED_SPECS)
def test_wave_of_one_matches_event_on_phased_specs(name):
    """The wave machinery with chronological selection IS the event loop
    — including the policy-visible labeling paths (stale's frozen
    labels, oracle's ground-truth substitution) on drifting traces.
    Decision/counter outputs must be IDENTICAL; float metrics are summed
    in a different association order (per-request vs per-wave), so they
    get a float32-accumulation tolerance."""
    spec = _shrunk(TG.PHASED_SPECS[name])
    tr = TG.generate(spec, 0)
    pols = (BL.MEDIC, BL.MEDIC_STALE, BL.MEDIC_ORACLE)
    ev = _sweep(tr, spec.n_warps, spec.lines_per_instr, pols, "event")
    wf = _sweep(tr, spec.n_warps, spec.lines_per_instr, pols, "wavefront",
                wave_size=1)
    for k in INT_KEYS:
        assert np.array_equal(ev[k], wf[k]), (name, k)
    for k in ev:
        if k in INT_KEYS:
            continue
        np.testing.assert_allclose(wf[k], ev[k], rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name}/{k}")


# ---------------------------------------------------------------------------
# the phased suite end-to-end on BOTH engines via one Experiment
# ---------------------------------------------------------------------------

def test_phased_experiment_runs_on_both_engines():
    """`Scenario.phased` suite through the declarative front door, same
    Experiment re-targeted per engine; engines agree within the
    differential envelope."""
    from repro.api import registry
    exp = registry.phased(("PHASED48",), name="phased48_x_engine")
    rs_wf = exp.run()
    rs_ev = exp.with_(engine="event").run()
    for pol in [p.name for p in exp.policies]:
        wf = float(np.asarray(rs_wf.value("ipc", scenario="PHASED48",
                                          policy=pol, seed=0)))
        ev = float(np.asarray(rs_ev.value("ipc", scenario="PHASED48",
                                          policy=pol, seed=0)))
        assert abs(wf - ev) / ev <= 0.02, (pol, wf, ev)
