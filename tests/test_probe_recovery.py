"""Probe-ratchet fix (PR 7): bypassed warps can be relabeled back up.

Before the fix the classifier window counted every valid request in the
ratio denominator while only every ``probe_interval``-th access of a
bypassing warp carried hit/miss evidence, so a bypassing warp's window
hit ratio was capped at ``1/probe_interval`` = 0.125 < the 0.2
mostly-miss threshold — labels ratcheted down and could never recover.
These tests pin the fixed behaviour at three altitudes:

  1. classifier-level: the window ratio is taken over the cache-path
     *sample* (``probed``), so an all-hit probe stream reads 1.0, not
     0.125, and the adaptive classify floor (``min_probe_samples``)
     lets small windows classify off few probes;
  2. a closed-loop ratchet emulation: a warp labeled ALL_MISS whose
     underlying behaviour turns all-hit is relabeled within two
     sampling windows even though it only probes every 8th access;
  3. engine-level: on the recovery-shaped PHASED_RECOVER48 spec, online
     MeDiC's final labels track the hit-heavy final phase while stale
     labels stay miss-shaped — plus the usual cross-engine parity rungs
     (wave_size=1 == event; fused == ref bitwise) on the new specs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import classifier as CLF
from repro.core import tracegen as TG
from repro.core import warp_types as WT
from repro.core.simulator import SimParams, simulate, simulate_sweep

PRM = SimParams()
PROBE = PRM.probe_interval            # 8: the engines' probe cadence


# ---------------------------------------------------------------------------
# 1. classifier-level: ratio over the probe sample, not the diluted stream
# ---------------------------------------------------------------------------

def _observe_stream(state, probed_seq, hit_seq, *, interval, warp=0):
    for p, h in zip(probed_seq, hit_seq):
        state = CLF.observe(state, jnp.asarray([warp]), jnp.asarray([h]),
                            sampling_interval=interval,
                            probed=jnp.asarray([p], jnp.int32),
                            probe_interval=PROBE)
    return state


def test_window_ratio_not_capped_by_probe_dilution():
    """A fully-bypassing warp probing every 8th access, all probes
    hitting: the window must read ratio 1.0 / ALL_HIT. Pre-fix it read
    8/64 = 0.125 -> ALL_MISS, the ratchet."""
    interval = 64
    probed = [1 if i % PROBE == PROBE - 1 else 0 for i in range(interval)]
    hits = [bool(p) for p in probed]
    s = _observe_stream(CLF.init(1), probed, hits, interval=interval)
    assert float(s.ratio[0]) == 1.0
    assert int(s.warp_type[0]) == WT.ALL_HIT


def test_min_samples_adapts_to_probe_cadence():
    """A 32-access window guarantees only 4 probes for a bypassing warp;
    the classify floor must admit them (clip(32//8, 1, 8) = 4) instead
    of bouncing the label to BALANCED every window."""
    interval = 32
    probed = [1 if i % PROBE == PROBE - 1 else 0 for i in range(interval)]
    hits = [bool(p) for p in probed]
    s = _observe_stream(CLF.init(1), probed, hits, interval=interval)
    assert int(s.sampled[0]) == 0                 # window closed + reset
    assert int(s.warp_type[0]) == WT.ALL_HIT      # 4 samples sufficed
    assert float(CLF.min_probe_samples(32, PROBE)) == 4.0
    assert float(CLF.min_probe_samples(256, PROBE)) == 8.0  # clipped
    assert float(CLF.min_probe_samples(8, PROBE)) == 1.0


def test_zero_sample_window_reverts_to_balanced():
    """A window that closes with no cache-path sample at all carries no
    evidence: the label reverts to the BALANCED prior rather than
    keeping a stale extreme."""
    interval = 16
    s = CLF.init(1)._replace(warp_type=jnp.asarray([WT.ALL_MISS]))
    s = _observe_stream(s, [0] * interval, [False] * interval,
                        interval=interval)
    assert int(s.warp_type[0]) == WT.BALANCED


def test_unprobed_requests_still_advance_the_cadence_clock():
    """``accesses`` must count bypassed-unprobed requests too — it is
    the window/probe cadence clock. If it froze, the window would never
    close and the probe phase would never come around again."""
    s = CLF.init(1)
    s = _observe_stream(s, [0] * 10, [False] * 10, interval=64)
    assert int(s.accesses[0]) == 10
    assert int(s.sampled[0]) == 0
    assert int(s.hits[0]) == 0


# ---------------------------------------------------------------------------
# 2. closed-loop ratchet emulation: relabel within K windows
# ---------------------------------------------------------------------------

def test_reformed_warp_relabels_within_two_windows():
    """Emulate the engine's bypass/probe feedback loop: a warp labeled
    ALL_MISS probes every 8th access; once the underlying behaviour
    turns all-hit, the label must ratchet back up within K=2 windows.
    Pre-fix this loop was absorbing: once miss-shaped, forever
    miss-shaped."""
    interval = 64
    s = CLF.init(1)
    # window 1: cache-path misses until the label turns miss-shaped,
    # then bypass with missing probes — the degrade direction works
    for _ in range(2 * interval):
        bypassing = bool(WT.is_bypass_type(s.warp_type[0]))
        probed = (int(s.accesses[0]) % PROBE == PROBE - 1) if bypassing \
            else True
        s = CLF.observe(s, jnp.asarray([0]), jnp.asarray([False]),
                        sampling_interval=interval,
                        probed=jnp.asarray([int(probed)], jnp.int32),
                        probe_interval=PROBE)
    assert int(s.warp_type[0]) == WT.ALL_MISS
    # drift: the warp's accesses would now all hit. Only probes see it.
    windows_before = int(s.windows[0])
    for _ in range(2 * interval):
        bypassing = bool(WT.is_bypass_type(s.warp_type[0]))
        probed = (int(s.accesses[0]) % PROBE == PROBE - 1) if bypassing \
            else True
        s = CLF.observe(s, jnp.asarray([0]), jnp.asarray([bool(probed)]),
                        sampling_interval=interval,
                        probed=jnp.asarray([int(probed)], jnp.int32),
                        probe_interval=PROBE)
        if int(s.warp_type[0]) >= WT.MOSTLY_HIT:
            break
    assert int(s.warp_type[0]) >= WT.MOSTLY_HIT
    assert int(s.windows[0]) - windows_before <= 2


# ---------------------------------------------------------------------------
# dilution fuzz: the 1/8 cap is gone for ANY bypass pattern. A
# deterministic grid always runs; hypothesis (when installed — the CI
# tier-2 job has it, the pinned runtime image may not) fuzzes the same
# checker over arbitrary interleavings.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def check_dilution_free(probed_seq, interval):
    """For ANY probed/unprobed interleaving where every cache-path
    sample hits, every closed window must read ratio 1.0 — the ratio is
    dilution-free — and the label must never turn miss-shaped. Pre-fix,
    any window with < 20% probed requests classified as mostly-miss
    despite a perfect probe hit streak."""
    s = CLF.init(1)
    for p in probed_seq:
        prev_windows = int(s.windows[0])
        s = CLF.observe(s, jnp.asarray([0]), jnp.asarray([bool(p)]),
                        sampling_interval=interval,
                        probed=jnp.asarray([int(p)], jnp.int32),
                        probe_interval=PROBE)
        assert int(s.hits[0]) == int(s.sampled[0])
        if int(s.windows[0]) > prev_windows:      # a window just closed
            assert float(s.ratio[0]) in (0.0, 1.0)  # 0.0 iff no sample
            assert not bool(WT.is_bypass_type(s.warp_type[0]))


@pytest.mark.parametrize("pattern,interval", [
    ("every8th", 16), ("every8th", 64),           # the engine cadence
    ("alternating", 32), ("rare", 48), ("burst", 32)])
def test_window_ratio_dilution_free_grid(pattern, interval):
    n = 4 * interval
    probed = {
        "every8th": [i % PROBE == PROBE - 1 for i in range(n)],
        "alternating": [i % 2 == 0 for i in range(n)],
        "rare": [i % 13 == 0 for i in range(n)],  # < 1/8 probed
        "burst": [(i % interval) < 4 for i in range(n)],
    }[pattern]
    check_dilution_free(probed, interval)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.booleans(), min_size=16, max_size=128),
           st.integers(min_value=16, max_value=64))
    def test_window_ratio_dilution_free_fuzz(probed_seq, interval):
        check_dilution_free(probed_seq, interval)


# ---------------------------------------------------------------------------
# 3. engine-level: recovery tracking + cross-engine parity on the new specs
# ---------------------------------------------------------------------------

def _run_one(pol, spec, tr, **kw):
    out = simulate(jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                   jnp.asarray(tr["compute_gap"]), n_warps=spec.n_warps,
                   lanes=spec.lines_per_instr, prm=PRM, pol=pol,
                   oracle_types=jnp.asarray(tr["oracle_wtype"]), **kw)
    return {k: np.asarray(v) for k, v in out.items()}


def test_online_labels_track_recovery_on_phased_recover48():
    """On the miss -> mixed -> hit spec, online MeDiC's final labels
    must follow the population into the hit-heavy final phase while
    frozen stale labels stay miss-shaped. Measured at seed 0: online
    ends 12.5% bypass-shaped / 75% hit-shaped; stale ends 87.5%
    bypass-shaped. Asserted with slack as a majority property."""
    spec = TG.PHASED_RECOVER_SPECS["PHASED_RECOVER48"]
    tr = TG.generate(spec, seed=0)
    online = _run_one(BL.MEDIC, spec, tr, engine="event")["warp_type"]
    stale = _run_one(BL.MEDIC_STALE, spec, tr, engine="event")["warp_type"]
    assert np.mean(online <= WT.MOSTLY_MISS) <= 0.25
    assert np.mean(online >= WT.MOSTLY_HIT) >= 0.5
    assert np.mean(stale <= WT.MOSTLY_MISS) >= 0.75
    # and the label recovery buys throughput, not just prettier labels
    ipc_on = _run_one(BL.MEDIC, spec, tr, engine="event")["ipc"]
    ipc_st = _run_one(BL.MEDIC_STALE, spec, tr, engine="event")["ipc"]
    assert float(ipc_on) > float(ipc_st)


@pytest.mark.parametrize("scen", ["PHASED_RECOVER48"])
def test_wave_of_one_matches_event_on_recover_specs(scen):
    """wave_size=1 IS the event loop — exact parity must extend to the
    recovery-shaped traces (per-instruction intensity schedule + the
    probe-sample observe path)."""
    spec = TG.PHASED_RECOVER_SPECS[scen]
    tr = TG.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM,
              oracle_types=jnp.asarray(tr["oracle_wtype"]))
    pols = BL.LABELING_LADDER
    ev = simulate_sweep(*args, pols, engine="event", **kw)
    wf = simulate_sweep(*args, pols, engine="wavefront", wave_size=1, **kw)
    for k in ev:
        # qdelay accumulates ~1e5 f32 addends over the long recovery
        # trace; summation-order skew leaves ~1e-5 relative residue on
        # the derived mean, so those two keys get one extra decade
        rtol = 1e-4 if k in ("qdelay_sum", "mean_qdelay") else 1e-5
        np.testing.assert_allclose(np.asarray(wf[k]), np.asarray(ev[k]),
                                   rtol=rtol, atol=1e-5, err_msg=k)


@pytest.mark.parametrize("scen", ["PHASED_RECOVER48"])
def test_fused_backend_bitwise_on_recover_specs(scen):
    """scan_backend="fused" must stay bit-identical to "ref" on the
    recovery traces — the fused observe path carries the same probed
    mask and adaptive classify floor as the reference."""
    spec = TG.PHASED_RECOVER_SPECS[scen]
    tr = TG.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM,
              engine="wavefront",
              oracle_types=jnp.asarray(tr["oracle_wtype"]))
    pols = BL.LABELING_LADDER
    outs = {b: simulate_sweep(*args, pols, scan_backend=b, **kw)
            for b in ("ref", "fused")}
    for k in outs["ref"]:
        assert np.array_equal(np.asarray(outs["ref"][k]),
                              np.asarray(outs["fused"][k]),
                              equal_nan=True), k
