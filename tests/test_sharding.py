"""Sharding rule resolution + small-mesh end-to-end partitioning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import (Logical, build_rules, spec_for, shard_act,
                            sharding_ctx, single_device_mesh)


def _mesh_16x16_abstract():
    # jax 0.4.37's AbstractMesh takes ((name, size), ...) pairs
    return jax.sharding.AbstractMesh((("data", 16), ("model", 16)))


def test_spec_basic():
    mesh = _mesh_16x16_abstract()
    rules = build_rules(mesh)
    s = spec_for(("embed", "heads", "head_dim"), (4096, 32, 128), mesh, rules)
    assert s == P("data", "model", None)


def test_spec_divisibility_fallback():
    mesh = _mesh_16x16_abstract()
    rules = build_rules(mesh)
    # 10 heads don't divide 16 -> unsharded
    s = spec_for(("embed", "heads", "head_dim"), (2560, 10, 256), mesh, rules)
    assert s == P("data", None, None)
    # 8 experts don't divide 16 -> expert falls back, mlp takes model
    s = spec_for(("expert", "embed", "mlp"), (8, 6144, 32768), mesh, rules)
    assert s == P(None, "data", "model")
    # 64 experts divide -> expert takes model, mlp falls back (axis used)
    s = spec_for(("expert", "embed", "mlp"), (64, 2048, 1024), mesh, rules)
    assert s == P("model", "data", None)


def test_spec_missing_mesh_axis_removed():
    mesh = _mesh_16x16_abstract()   # no "pod" axis
    rules = build_rules(mesh)
    s = spec_for(("batch", None), (256, 4096), mesh, rules)
    assert s == P("data", None)


def test_multipod_batch_axes():
    mesh = jax.sharding.AbstractMesh(
        (("pod", 2), ("data", 16), ("model", 16)))
    rules = build_rules(mesh)
    s = spec_for(("batch", None), (256, 4096), mesh, rules)
    assert s == P(("pod", "data"), None)
    # batch=1 (long_500k): not divisible -> unsharded
    s = spec_for(("batch", "kv_seq"), (1, 524288), mesh, rules)
    assert s == P(None, "model")


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["batch", "embed", "heads", "mlp", "vocab",
                                 "expert", None]), min_size=1, max_size=4),
       st.lists(st.sampled_from([1, 2, 7, 16, 48, 64, 256, 4096]),
                min_size=4, max_size=4))
def test_spec_never_overassigns(axes, dims):
    """Property: every produced spec uses each mesh axis at most once and
    always divides the dimension."""
    mesh = _mesh_16x16_abstract()
    rules = build_rules(mesh)
    shape = tuple(dims[: len(axes)])
    s = spec_for(tuple(axes), shape, mesh, rules)
    used = []
    for dim, assignment in zip(shape, tuple(s)):
        if assignment is None:
            continue
        axs = (assignment,) if isinstance(assignment, str) else assignment
        size = 1
        for a in axs:
            assert a not in used
            used.append(a)
            size *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
        assert dim % size == 0


def test_shard_act_noop_without_ctx():
    x = jnp.ones((4, 4))
    y = shard_act(x, "batch", None)
    assert y is x


def test_model_logical_trees_cover_params():
    """Every param leaf has a Logical leaf of matching rank."""
    from repro.configs.base import get_config
    from repro.models.model import build_model
    for arch in ("grok_1_314b", "whisper_tiny", "xlstm_125m"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        lg = model.logical_params()
        def chk(l, s):
            assert isinstance(l, Logical)
            assert len(l.axes) == len(s.shape), (l.axes, s.shape)
        jax.tree.map(chk, lg, shapes,
                     is_leaf=lambda x: isinstance(x, Logical))
