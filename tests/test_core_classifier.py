"""Unit + property tests for warp-type taxonomy and the online classifier."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
from hypothesis import given, settings

from repro.core import classifier as CLF
from repro.core import warp_types as WT


def test_classify_boundaries():
    acc = jnp.full((6,), 100, jnp.int32)
    ratios = jnp.asarray([0.0, 0.1, 0.2, 0.5, 0.85, 1.0])
    t = WT.classify(ratios, acc)
    assert list(np.asarray(t)) == [WT.ALL_MISS, WT.MOSTLY_MISS,
                                   WT.MOSTLY_MISS, WT.BALANCED,
                                   WT.MOSTLY_HIT, WT.ALL_HIT]


def test_classify_insufficient_samples_defaults_balanced():
    t = WT.classify(jnp.asarray([1.0]), jnp.asarray([2]), min_samples=8)
    assert int(t[0]) == WT.BALANCED


def test_policy_predicates():
    assert bool(WT.is_bypass_type(jnp.int32(WT.ALL_MISS)))
    assert bool(WT.is_bypass_type(jnp.int32(WT.MOSTLY_MISS)))
    assert not bool(WT.is_bypass_type(jnp.int32(WT.BALANCED)))
    assert bool(WT.is_priority_type(jnp.int32(WT.MOSTLY_HIT)))
    assert bool(WT.is_priority_type(jnp.int32(WT.ALL_HIT)))
    assert not bool(WT.is_priority_type(jnp.int32(WT.BALANCED)))


def test_insertion_rank_ordering():
    ranks = [int(WT.insertion_rank(jnp.int32(t))) for t in range(5)]
    # higher utility -> lower rank (evicted later)
    assert ranks[WT.ALL_HIT] <= ranks[WT.MOSTLY_HIT] < ranks[WT.BALANCED] \
        <= ranks[WT.MOSTLY_MISS] == ranks[WT.ALL_MISS]


def test_classifier_converges_to_behavior():
    st8 = CLF.init(2)
    # warp 0 always hits, warp 1 always misses
    for _ in range(40):
        st8 = CLF.observe(st8, jnp.asarray([0, 1]),
                          jnp.asarray([True, False]),
                          sampling_interval=16)
    assert int(st8.warp_type[0]) == WT.ALL_HIT
    assert int(st8.warp_type[1]) == WT.ALL_MISS


def test_classifier_adapts_to_phase_change():
    st8 = CLF.init(1)
    for _ in range(32):
        st8 = CLF.observe(st8, jnp.asarray([0]), jnp.asarray([True]),
                          sampling_interval=16)
    assert int(st8.warp_type[0]) == WT.ALL_HIT
    for _ in range(32):
        st8 = CLF.observe(st8, jnp.asarray([0]), jnp.asarray([False]),
                          sampling_interval=16)
    assert int(st8.warp_type[0]) == WT.ALL_MISS


@settings(max_examples=30, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=200),
       st.integers(min_value=4, max_value=64))
def test_classifier_counters_invariant(outcomes, interval):
    """hits <= accesses < interval always; ratio in [0,1]."""
    s = CLF.init(1)
    for o in outcomes:
        s = CLF.observe(s, jnp.asarray([0]), jnp.asarray([o]),
                        sampling_interval=interval)
        assert 0 <= int(s.hits[0]) <= int(s.accesses[0]) < interval
        assert 0.0 <= float(s.ratio[0]) <= 1.0
        assert 0 <= int(s.warp_type[0]) < WT.NUM_TYPES


@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=0, max_value=1),
       st.integers(min_value=8, max_value=1000))
def test_classify_total_and_monotone(ratio, acc):
    """Every ratio maps to exactly one type; type is monotone in ratio."""
    t1 = int(WT.classify(jnp.float32(ratio), jnp.int32(acc)))
    t2 = int(WT.classify(jnp.float32(min(ratio + 0.3, 1.0)), jnp.int32(acc)))
    assert 0 <= t1 < WT.NUM_TYPES
    assert t2 >= t1
