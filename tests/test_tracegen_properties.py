"""Property tests for trace invariants (ISSUE 2 satellite).

The invariants the simulator's warp-type story rests on:

  I1  mix fractions respected — archetype counts match the spec mixture
      within binomial tolerance;
  I2  private working sets disjoint across warps — warp w only ever
      reuses lines from its own [(w+1)<<13, (w+2)<<13) region;
  I3  streaming addresses never collide with working sets (or the
      shared pool, or another warp's stream);
  I4  archetype stability — without phase shifts a warp's line universe
      is identical in both kernel halves (Fig 4's premise).

A deterministic grid (all 15 workloads + the stress matrix) always runs;
when hypothesis is installed the same checker fuzzes the TraceSpec space
(the CI tier-2 job installs it; the pinned runtime image may not).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import tracegen as TG
from repro.core import workloads as WL
from repro.core.tracegen.spec import make_layout

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def check_invariants(spec: TG.TraceSpec, seed: int) -> None:
    layout = make_layout(spec)
    tr = TG.generate(spec, seed)
    lines, arch = tr["lines"], tr["archetype"]
    w_n = spec.n_warps
    assert lines.max() < 2 ** 31 and lines.min() >= 0

    # I1 — mixture respected (binomial 5-sigma + discreteness slack)
    counts = np.bincount(arch, minlength=len(spec.mix))
    for a, p in enumerate(spec.mix):
        sigma = np.sqrt(max(p * (1 - p), 1e-9) / w_n)
        assert abs(counts[a] / w_n - p) <= 5 * sigma + 2 / w_n, \
            (spec.name, a, counts[a] / w_n, p)

    # I2 — working-set lines stay in their own warp's private region
    wi = np.arange(w_n, dtype=np.int64)[None, :, None]
    ws_mask = (lines >= layout.pool_region) & (lines < layout.fresh_base)
    owner = (lines.astype(np.int64) >> 13) - 1
    assert bool(np.all(owner[ws_mask] == np.broadcast_to(
        wi, lines.shape)[ws_mask])), spec.name

    # I3 — streaming region disjoint from every working set and the pool,
    # and each warp streams only inside its own stripe
    fresh_mask = lines >= layout.fresh_base
    offs = lines.astype(np.int64) - layout.fresh_base
    stripe = offs // layout.fresh_stride
    assert bool(np.all(stripe[fresh_mask] == np.broadcast_to(
        wi, lines.shape)[fresh_mask])), spec.name
    # all_miss warps (empty working set) must be pure streaming
    tab = spec.archetype_table()
    dead = np.flatnonzero((tab[arch, 0] == 0)
                          & (tab[tr["archetype2"], 0] == 0))
    if dead.size:
        assert bool(np.all(fresh_mask[:, dead, :])), spec.name

    # I4 — stability: without phase shifts, every reuse (non-streaming)
    # line in EITHER half comes from the warp's single lowered working
    # set (or the shared pool) — the same universe all kernel long
    if not spec.phase_shift:
        assert np.array_equal(arch, tr["archetype2"])
        _, wp = TG.lower(spec, [seed])
        half = spec.n_instr // 2
        pool_set = set(wp.pool[0].tolist())
        for w in range(0, w_n, max(w_n // 8, 1)):
            size = int(wp.ws_size[0, w, 0])
            allowed = set(wp.ws_table[0, w, :size].tolist()) | pool_set
            for sl in (slice(0, half), slice(half, None)):
                used = lines[sl, w][~fresh_mask[sl, w]]
                assert set(used.ravel().tolist()) <= allowed, (spec.name, w)


@pytest.mark.parametrize("workload", WL.WORKLOAD_NAMES)
def test_invariants_paper_workloads(workload):
    spec = TG.TraceSpec.from_workload(WL.WORKLOADS[workload])
    check_invariants(spec, seed=0)


@pytest.mark.parametrize("name", TG.STRESS_SPECS)
def test_invariants_stress_matrix(name):
    check_invariants(TG.STRESS_SPECS[name], seed=1)


def test_mix_fraction_converges_at_scale():
    """I1 sharpens with warp count: at 4096 warps every archetype
    fraction lands within 3 points of the spec mixture."""
    spec = dataclasses.replace(
        TG.TraceSpec.from_workload(WL.WORKLOADS["BFS"]), n_warps=4096)
    arch = TG.generate(spec, 0)["archetype"]
    frac = np.bincount(arch, minlength=5) / spec.n_warps
    np.testing.assert_allclose(frac, spec.mix, atol=0.03)


def test_phase_shift_flip_rate():
    spec = TG.STRESS_SPECS["PHASE2K"]
    tr = TG.generate(spec, 0)
    flipped = float(np.mean(tr["archetype"] != tr["archetype2"]))
    # flip_prob, minus picks that landed on the same archetype (~1/5)
    expected = spec.phase_flip_prob * (1 - 1 / len(spec.mix))
    assert abs(flipped - expected) < 0.05, (flipped, expected)


def test_non_phase_shift_never_flips():
    for w in ("BFS", "CONS"):
        spec = TG.TraceSpec.from_workload(WL.WORKLOADS[w])
        tr = TG.generate(spec, 2)
        assert np.array_equal(tr["archetype"], tr["archetype2"])


if HAVE_HYPOTHESIS:
    @st.composite
    def trace_specs(draw):
        n_arch = 5
        weights = [draw(st.integers(0, 10)) for _ in range(n_arch)]
        if sum(weights) == 0:
            weights[draw(st.integers(0, n_arch - 1))] = 1
        total = sum(weights)
        mix = tuple(x / total for x in weights)
        return TG.TraceSpec(
            name=draw(st.sampled_from(["fuzzA", "fuzzB", "fuzzC"])),
            mix=mix,
            intensity=draw(st.floats(0.0, 1.0)),
            n_warps=draw(st.integers(1, 192)),
            n_instr=2 * draw(st.integers(1, 16)),
            lines_per_instr=draw(st.integers(1, 8)),
            n_pcs=draw(st.integers(1, 12)),
            phase_shift=draw(st.booleans()),
            phase_flip_prob=draw(st.floats(0.0, 1.0)),
            shared_boost=draw(st.floats(0.0, 8.0)),
        )

    @settings(max_examples=40, deadline=None)
    @given(spec=trace_specs(), seed=st.integers(0, 2 ** 31 - 1))
    def test_invariants_fuzzed(spec, seed):
        check_invariants(spec, seed)

    @settings(max_examples=15, deadline=None)
    @given(spec=trace_specs(), seed=st.integers(0, 2 ** 31 - 1))
    def test_loop_parity_fuzzed(spec, seed):
        small = dataclasses.replace(spec, n_warps=min(spec.n_warps, 24),
                                    n_instr=min(spec.n_instr, 8))
        vec = TG.generate(small, seed)
        ref = TG.generate_ref(small, seed)
        for key in ("lines", "pcs", "archetype", "archetype2"):
            assert np.array_equal(vec[key], ref[key]), key
