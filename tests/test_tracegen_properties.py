"""Property tests for trace invariants (ISSUE 2 satellite).

The invariants the simulator's warp-type story rests on:

  I1  mix fractions respected — archetype counts match the spec mixture
      within binomial tolerance;
  I2  private working sets disjoint across warps — warp w only ever
      reuses lines from its own [(w+1)<<13, (w+2)<<13) region;
  I3  streaming addresses never collide with working sets (or the
      shared pool, or another warp's stream);
  I4  archetype stability — without phase shifts a warp's line universe
      is identical in both kernel halves (Fig 4's premise).

A deterministic grid (all 15 workloads + the stress matrix) always runs;
when hypothesis is installed the same checker fuzzes the TraceSpec space
(the CI tier-2 job installs it; the pinned runtime image may not).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import tracegen as TG
from repro.core import workloads as WL
from repro.core.tracegen.spec import make_layout

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def check_invariants(spec: TG.TraceSpec, seed: int) -> None:
    layout = make_layout(spec)
    tr = TG.generate(spec, seed)
    lines, arch = tr["lines"], tr["archetype"]
    w_n = spec.n_warps
    assert lines.max() < 2 ** 31 and lines.min() >= 0

    # I1 — mixture respected (binomial 5-sigma + discreteness slack);
    # ``archetype`` is the phase-0 draw, so the reference mixture is the
    # first phase's (falling back to the spec's base mix)
    mix0 = spec.mix
    if spec.phases is not None and spec.phases[0].mix is not None:
        mix0 = spec.phases[0].mix
    counts = np.bincount(arch, minlength=len(mix0))
    for a, p in enumerate(mix0):
        sigma = np.sqrt(max(p * (1 - p), 1e-9) / w_n)
        assert abs(counts[a] / w_n - p) <= 5 * sigma + 2 / w_n, \
            (spec.name, a, counts[a] / w_n, p)

    # I2 — working-set lines stay in their own warp's private region
    wi = np.arange(w_n, dtype=np.int64)[None, :, None]
    ws_mask = (lines >= layout.pool_region) & (lines < layout.fresh_base)
    owner = (lines.astype(np.int64) >> 13) - 1
    assert bool(np.all(owner[ws_mask] == np.broadcast_to(
        wi, lines.shape)[ws_mask])), spec.name

    # I3 — streaming region disjoint from every working set and the pool,
    # and each warp streams only inside its own stripe
    fresh_mask = lines >= layout.fresh_base
    offs = lines.astype(np.int64) - layout.fresh_base
    stripe = offs // layout.fresh_stride
    assert bool(np.all(stripe[fresh_mask] == np.broadcast_to(
        wi, lines.shape)[fresh_mask])), spec.name
    # warps whose working set is empty in EVERY phase must stream purely
    tab = spec.archetype_table()
    dead = np.flatnonzero(np.all(tab[tr["archetype_phases"], 0] == 0,
                                 axis=1))
    if dead.size:
        assert bool(np.all(fresh_mask[:, dead, :])), spec.name

    # I4 — per-phase reuse universe: every reuse (non-streaming) line an
    # instruction of phase p draws comes from the warp's phase-p lowered
    # working set or the shared pool. For a static spec all phases share
    # one universe (Fig 4's stability premise); for phased specs this
    # pins the address structure AT each phase boundary — churned
    # working sets swap universes exactly where the schedule says.
    if not spec.phase_shift and spec.phases is None:
        assert np.array_equal(arch, tr["archetype2"])
    _, wp = TG.lower(spec, [seed])
    phase_of = TG.phase_of_instr(spec)
    pool_set = set(wp.pool[0].tolist())
    for w in range(0, w_n, max(w_n // 8, 1)):
        for p in range(wp.n_phases):
            rows = np.flatnonzero(phase_of == p)
            if rows.size == 0:
                continue
            size = int(wp.ws_size[0, w, p])
            allowed = set(wp.ws_table[0, w, p, :size].tolist()) | pool_set
            used = lines[rows][:, w][~fresh_mask[rows][:, w]]
            assert set(used.ravel().tolist()) <= allowed, \
                (spec.name, w, p)

    # I5 — oracle labels are piecewise-constant on phases and in range
    oracle = tr["oracle_wtype"]
    assert oracle.min() >= 0 and oracle.max() < 5
    for p in range(wp.n_phases):
        rows = np.flatnonzero(phase_of == p)
        if rows.size:
            assert bool(np.all(oracle[rows] == oracle[rows[0]])), \
                (spec.name, p)


@pytest.mark.parametrize("workload", WL.WORKLOAD_NAMES)
def test_invariants_paper_workloads(workload):
    spec = TG.TraceSpec.from_workload(WL.WORKLOADS[workload])
    check_invariants(spec, seed=0)


@pytest.mark.parametrize("name", TG.STRESS_SPECS)
def test_invariants_stress_matrix(name):
    check_invariants(TG.STRESS_SPECS[name], seed=1)


@pytest.mark.parametrize("name", TG.PHASED_SPECS)
def test_invariants_phased_family(name):
    check_invariants(TG.PHASED_SPECS[name], seed=1)


@pytest.mark.parametrize("name", TG.PHASED_RECOVER_SPECS)
def test_invariants_phased_recover_family(name):
    check_invariants(TG.PHASED_RECOVER_SPECS[name], seed=1)


def test_mix_fraction_converges_at_scale():
    """I1 sharpens with warp count: at 4096 warps every archetype
    fraction lands within 3 points of the spec mixture."""
    spec = dataclasses.replace(
        TG.TraceSpec.from_workload(WL.WORKLOADS["BFS"]), n_warps=4096)
    arch = TG.generate(spec, 0)["archetype"]
    frac = np.bincount(arch, minlength=5) / spec.n_warps
    np.testing.assert_allclose(frac, spec.mix, atol=0.03)


def test_phase_shift_flip_rate():
    spec = TG.STRESS_SPECS["PHASE2K"]
    tr = TG.generate(spec, 0)
    flipped = float(np.mean(tr["archetype"] != tr["archetype2"]))
    # flip_prob, minus picks that landed on the same archetype (~1/5)
    expected = spec.phase_flip_prob * (1 - 1 / len(spec.mix))
    assert abs(flipped - expected) < 0.05, (flipped, expected)


def test_non_phase_shift_never_flips():
    for w in ("BFS", "CONS"):
        spec = TG.TraceSpec.from_workload(WL.WORKLOADS[w])
        tr = TG.generate(spec, 2)
        assert np.array_equal(tr["archetype"], tr["archetype2"])


if HAVE_HYPOTHESIS:
    @st.composite
    def archetype_mixes(draw):
        n_arch = 5
        weights = [draw(st.integers(0, 10)) for _ in range(n_arch)]
        if sum(weights) == 0:
            weights[draw(st.integers(0, n_arch - 1))] = 1
        total = sum(weights)
        return tuple(x / total for x in weights)

    @st.composite
    def phase_schedules(draw):
        """Random drift schedules: 1–4 phases with random lengths,
        optional per-phase mixes/flip/churn/intensity — the TraceSpec
        surface the phased family opens (ISSUE 5)."""
        n_ph = draw(st.integers(1, 4))
        return tuple(
            TG.Phase(
                frac=draw(st.floats(0.05, 3.0)),
                mix=draw(st.one_of(st.none(), archetype_mixes())),
                flip_prob=draw(st.one_of(st.none(), st.floats(0.0, 1.0))),
                churn=draw(st.floats(0.0, 1.0)),
                intensity=draw(st.one_of(st.none(), st.floats(0.0, 1.0))),
            ) for _ in range(n_ph))

    @st.composite
    def trace_specs(draw):
        phases = draw(st.one_of(st.none(), phase_schedules()))
        return TG.TraceSpec(
            name=draw(st.sampled_from(["fuzzA", "fuzzB", "fuzzC"])),
            mix=draw(archetype_mixes()),
            intensity=draw(st.floats(0.0, 1.0)),
            n_warps=draw(st.integers(1, 192)),
            n_instr=2 * draw(st.integers(1, 16)),
            lines_per_instr=draw(st.integers(1, 8)),
            n_pcs=draw(st.integers(1, 12)),
            # phases and the legacy mid-kernel flip are exclusive
            phase_shift=draw(st.booleans()) if phases is None else False,
            phase_flip_prob=draw(st.floats(0.0, 1.0)),
            shared_boost=draw(st.floats(0.0, 8.0)),
            phases=phases,
        )

    @settings(max_examples=40, deadline=None)
    @given(spec=trace_specs(), seed=st.integers(0, 2 ** 31 - 1))
    def test_invariants_fuzzed(spec, seed):
        check_invariants(spec, seed)

    @settings(max_examples=15, deadline=None)
    @given(spec=trace_specs(), seed=st.integers(0, 2 ** 31 - 1))
    def test_loop_parity_fuzzed(spec, seed):
        small = dataclasses.replace(spec, n_warps=min(spec.n_warps, 24),
                                    n_instr=min(spec.n_instr, 8))
        vec = TG.generate(small, seed)
        ref = TG.generate_ref(small, seed)
        for key in ("lines", "pcs", "archetype", "archetype2",
                    "oracle_wtype", "archetype_phases"):
            assert np.array_equal(vec[key], ref[key]), key
        assert np.array_equal(np.asarray(vec["compute_gap"]),
                              np.asarray(ref["compute_gap"]))

    @settings(max_examples=20, deadline=None)
    @given(phases=phase_schedules(), seed=st.integers(0, 2 ** 31 - 1),
           n_instr=st.integers(1, 12))
    def test_phase_boundary_parity_fuzzed(phases, seed, n_instr):
        """ref==vectorized exact parity and per-phase address-region
        structure at EVERY phase boundary, over random schedules whose
        rounded boundaries include degenerate (zero-length) phases."""
        spec = TG.TraceSpec("fuzzP", mix=(0.2, 0.2, 0.2, 0.2, 0.2),
                            intensity=0.9, n_warps=16, n_instr=2 * n_instr,
                            lines_per_instr=4, phases=phases)
        bounds, _ = TG.compile_schedule(spec)
        assert bounds[0] == 0 and bounds[-1] == spec.n_instr
        assert np.all(np.diff(bounds) >= 0)
        vec = TG.generate(spec, seed)
        ref = TG.generate_ref(spec, seed)
        for key in ("lines", "pcs", "oracle_wtype", "archetype_phases"):
            assert np.array_equal(vec[key], ref[key]), key
        check_invariants(spec, seed)
