"""Declarative experiment API (repro.api): plan compiler, ResultSet,
facade parity, and the engine front-door validation (ISSUE 4).

Covers the acceptance criteria:
  * the plan compiler emits <= one jitted call per (trace-shape, engine)
    bucket — asserted both on the compiled plan and on the ACTUAL number
    of dispatches (counter) and jit-cache entries (trace counter);
  * label selection round-trips (to_rows / sel / get / to_json);
  * Experiment output equals hand-rolled ``simulate_sweep`` output
    exactly, on 3 workloads x both engines;
  * ``wave_size`` with a non-wavefront engine raises, and the ENGINES
    membership error goes through the same front door.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api import registry
from repro.core import baselines as BL
from repro.core import engine as ENG
from repro.core.simulator import SimParams, simulate, simulate_sweep

PRM = SimParams()
POLICIES = (BL.BASELINE, BL.MEDIC)
WORKLOADS3 = ("BFS", "BP", "CONS")


def _exp(workloads=WORKLOADS3, policies=POLICIES, engine="event", **kw):
    return api.Experiment(
        f"t:{engine}:{'-'.join(workloads)}",
        tuple(api.Scenario.workload(w) for w in workloads),
        policies, engine=engine, **kw)


# ---------------------------------------------------------------------------
# plan compiler
# ---------------------------------------------------------------------------

def test_same_shape_scenarios_compile_to_one_call():
    plan = _exp().compile()
    assert plan.n_calls == 1
    assert plan.n_executables == 1
    assert plan.calls[0].flat == 3
    assert tuple(s.name for s in plan.calls[0].scenarios) == WORKLOADS3


def test_mixed_shapes_bucket_per_shape():
    scens = (api.Scenario.workload("BFS"),
             api.Scenario.workload("BP"),
             api.Scenario.workload("BFS", n_warps=96, name="BFS96"),
             api.Scenario.workload("BP", n_warps=96, name="BP96"))
    plan = api.Experiment("t:mixed", scens, POLICIES).compile()
    assert plan.n_calls == 2
    shapes = {c.shape for c in plan.calls}
    assert shapes == {(64, 48, 16), (64, 96, 16)}
    # every scenario appears exactly once across calls
    names = [s.name for c in plan.calls for s in c.scenarios]
    assert sorted(names) == sorted(s.name for s in scens)


def test_plan_executes_one_dispatch_per_bucket(monkeypatch):
    """The ACTUAL dispatch count equals the plan's call count, and the
    underlying jit cache grows by at most one trace per bucket."""
    import repro.api.experiment as EXP

    calls = []
    real = EXP.simulate_sweep

    def counting(*a, **kw):
        calls.append(kw.get("engine", "event"))
        return real(*a, **kw)

    monkeypatch.setattr(EXP, "simulate_sweep", counting)
    exp = _exp()                       # 3 same-shape scenarios
    cache_before = ENG._simulate_batch._cache_size()
    rs = exp.run()
    assert len(calls) == exp.compile().n_calls == 1
    # one bucket -> at most one new compiled executable
    assert ENG._simulate_batch._cache_size() - cache_before <= 1
    # a second run re-dispatches but compiles nothing new
    cache_warm = ENG._simulate_batch._cache_size()
    exp.run()
    assert ENG._simulate_batch._cache_size() == cache_warm
    assert len(calls) == 2
    assert rs.meta["n_calls"] == 1


def test_registry_plans_are_minimal():
    assert registry.PAPER_FIG7.compile().n_calls == 1      # one 48-warp shape
    assert len(registry.PAPER_FIG7.scenarios) == 15
    stress_plan = registry.STRESS.compile()
    assert stress_plan.n_calls == 3                        # 1k / 2k / 4k warps
    assert {c.engine for c in stress_plan.calls} == {"wavefront"}
    assert registry.get("paper_fig7") is registry.PAPER_FIG7
    with pytest.raises(KeyError):
        registry.get("nope")


# ---------------------------------------------------------------------------
# facade parity: Experiment == hand-rolled simulate_sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ("event", "wavefront"))
def test_experiment_equals_handrolled_sweep(engine):
    """3 workloads x 2 engines: the api's one bucketed call returns
    exactly what hand-rolling the same stacked ``simulate_sweep`` call
    returns — no approximation enters through the api layer."""
    exp = _exp(engine=engine)
    rs = exp.run()

    parts = [s.materialize() for s in exp.scenarios]
    lines = np.concatenate([p["lines"] for p in parts])
    pcs = np.concatenate([p["pcs"] for p in parts])
    gap = np.concatenate([p["compute_gap"] for p in parts])
    hand = simulate_sweep(jnp.asarray(lines), jnp.asarray(pcs),
                          jnp.asarray(gap), POLICIES, n_warps=48, lanes=16,
                          prm=PRM, engine=engine)
    hand = {k: np.asarray(v) for k, v in hand.items()}     # [P, F, ...]

    for fi, wl in enumerate(WORKLOADS3):
        for pi, pol in enumerate(POLICIES):
            got = rs.get(scenario=wl, policy=pol.name, seed=0)
            assert set(got) == set(hand)
            for k in hand:
                np.testing.assert_array_equal(
                    got[k], hand[k][pi, fi], err_msg=f"{wl}/{pol.name}/{k}")


def test_single_scenario_matches_simulate():
    """A 1-scenario, 1-policy experiment equals the plain ``simulate``
    facade (which the policy-engine suite pins against the sweep)."""
    exp = api.Experiment("t:one", (api.Scenario.workload("SSSP"),),
                         (BL.MEDIC,))
    rs = exp.run()
    tr = exp.scenarios[0].materialize()
    ref = simulate(jnp.asarray(tr["lines"][0]), jnp.asarray(tr["pcs"][0]),
                   jnp.asarray(tr["compute_gap"][0]), n_warps=48, lanes=16,
                   prm=PRM, pol=BL.MEDIC)
    got = rs.get(policy="MeDiC")
    for k, v in ref.items():
        np.testing.assert_array_equal(got[k], np.asarray(v), err_msg=k)


# ---------------------------------------------------------------------------
# ResultSet labeling
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def rset():
    exp = api.Experiment(
        "t:labels",
        (api.Scenario.workload("BFS", seeds=(0, 1)),
         api.Scenario.workload("BP")),
        POLICIES)
    return exp.run(keep_traces=True)


def test_resultset_axes(rset):
    assert rset.policies == ("Baseline", "MeDiC")
    assert rset.scenarios == ("BFS", "BP")
    assert rset.seeds("BFS") == (0, 1)
    assert rset.seeds("BP") == (0,)
    assert "ipc" in rset.scalar_metrics()
    assert "warp_hit_ratio" in rset.metrics
    assert "warp_hit_ratio" not in rset.scalar_metrics()


def test_to_rows_round_trips(rset):
    rows = rset.to_rows()
    # one row per (scenario, policy, seed): (2 seeds + 1 seed) x 2 policies
    assert len(rows) == 6
    keys = {(r["scenario"], r["policy"], r["seed"]) for r in rows}
    assert len(keys) == 6
    for r in rows:
        assert r["ipc"] == rset.value("ipc", r["scenario"], r["policy"],
                                      r["seed"])


def test_sel_restricts_and_chains(rset):
    medic = rset.sel(policy="MeDiC")
    assert medic.policies == ("MeDiC",)
    assert len(medic.to_rows()) == 3
    one = medic.sel(scenario="BP")
    # fully pinned: get() needs no arguments
    assert float(one.get()["ipc"]) == rset.value("ipc", "BP", "MeDiC", 0)
    with pytest.raises(KeyError):
        rset.sel(policy="NoSuch")
    with pytest.raises(KeyError):
        rset.sel(scenario="NoSuch")
    with pytest.raises(KeyError):
        rset.sel(seed=3)
    with pytest.raises(KeyError):
        rset.get(scenario="BFS", policy="MeDiC")   # seed ambiguous


def test_speedup_over(rset):
    sp = rset.speedup_over("Baseline")
    assert sp["BFS"]["Baseline"] == pytest.approx(1.0)
    assert sp["BFS"]["MeDiC"] > 1.0
    per_seed = rset.speedup_over("Baseline", reduce=None)
    assert len(per_seed["BFS"]["MeDiC"]) == 2
    assert sp["BFS"]["MeDiC"] == pytest.approx(
        np.mean(per_seed["BFS"]["MeDiC"]))


def test_to_json_and_traces(rset):
    doc = json.loads(rset.to_json())
    assert doc["policies"] == ["Baseline", "MeDiC"]
    assert len(doc["rows"]) == 6
    assert doc["meta"]["n_calls"] == 1
    tr = rset.trace("BFS", 1)
    assert tr["lines"].shape == (64, 48, 16)
    # traces are the scenario's own materialization, by seed
    np.testing.assert_array_equal(
        tr["lines"], api.Scenario.workload("BFS", seeds=(0, 1))
        .materialize()["lines"][1])
    rs2 = api.Experiment("t:notrace", (api.Scenario.workload("BP"),),
                         (BL.BASELINE,)).run()
    with pytest.raises(ValueError):
        rs2.trace("BP", 0)


# ---------------------------------------------------------------------------
# validation (satellite): one shared front door
# ---------------------------------------------------------------------------

def test_wave_size_with_event_engine_raises():
    scen = api.Scenario.workload("BFS")
    tr = scen.materialize()
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    with pytest.raises(ValueError, match="wave_size"):
        simulate_sweep(*args, POLICIES, n_warps=48, lanes=16, prm=PRM,
                       engine="event", wave_size=8)
    with pytest.raises(ValueError, match="wave_size"):
        simulate(jnp.asarray(tr["lines"][0]), jnp.asarray(tr["pcs"][0]),
                 jnp.asarray(tr["compute_gap"][0]), n_warps=48, lanes=16,
                 prm=PRM, pol=BL.MEDIC, engine="event", wave_size=8)
    with pytest.raises(ValueError, match="wave_size"):
        api.Experiment("t:bad", (scen,), POLICIES, engine="event",
                       wave_size=8)
    with pytest.raises(ValueError, match="wave_size"):
        ENG.validate_engine_args("wavefront", wave_size=0)
    with pytest.raises(ValueError, match="integer"):
        ENG.validate_engine_args("wavefront", wave_size=2.5)


def test_unknown_engine_routes_through_front_door():
    scen = api.Scenario.workload("BFS")
    tr = scen.materialize()
    with pytest.raises(ValueError, match="unknown engine"):
        simulate_sweep(jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                       jnp.asarray(tr["compute_gap"]), POLICIES,
                       n_warps=48, lanes=16, prm=PRM, engine="warp9")
    with pytest.raises(ValueError, match="unknown engine"):
        api.Experiment("t:bad2", (scen,), POLICIES, engine="warp9")


def test_experiment_validation():
    scen = api.Scenario.workload("BFS")
    with pytest.raises(ValueError, match="scenario"):
        api.Experiment("t:empty", (), POLICIES)
    with pytest.raises(ValueError, match="policy"):
        api.Experiment("t:nopol", (scen,), ())
    with pytest.raises(ValueError, match="duplicate scenario"):
        api.Experiment("t:dup", (scen, api.Scenario.workload("BFS")),
                       POLICIES)
    with pytest.raises(ValueError, match="duplicate policy"):
        api.Experiment("t:duppol", (scen,), (BL.MEDIC, BL.MEDIC))
    with pytest.raises(ValueError, match="seed"):
        api.Scenario.workload("BFS", seeds=())
    with pytest.raises(ValueError, match="duplicate seeds"):
        api.Scenario.workload("BFS", seeds=(0, 0))
    with pytest.raises(ValueError, match="unknown workload"):
        api.Scenario.workload("NOPE")
    with pytest.raises(ValueError, match="unknown stress"):
        api.Scenario.stress("NOPE")


def test_scenario_hashable_and_overrides():
    a = api.Scenario.workload("BFS")
    b = api.Scenario.workload("BFS")
    assert a == b and hash(a) == hash(b)
    big = api.Scenario.workload("BFS", n_warps=128, name="BFS128")
    assert big.shape == (64, 128, 16)
    assert big.trace_spec.n_warps == 128
    assert {a, b, big} == {a, big}
