"""Multi-device sharded sweeps: parity + the sharding-layer bug tail
(ISSUE 10).

Three tiers:

  * resolution tests on ``AbstractMesh`` grids — always run, no devices
    needed: the size-1-axis contract of ``spec_for`` / ``resolve_axes``
    ((1,N) / (N,1) / (2,2) meshes), ``make_local_mesh`` error quality,
    and the ``Experiment`` / ``validate_mesh_args`` front-door checks;
  * in-process parity + the ``shard_act`` (1,N)-mesh regression — need
    >= 2 jax devices (the tier2-sharded CI job provides 8 via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), skipped on
    a single-device box;
  * one subprocess smoke that sets ``XLA_FLAGS`` itself before the
    first jax import, so plain tier-1 on a 1-device box still
    exercises the multi-device paths end to end every run.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as SH
from repro.api import registry
from repro.api.experiment import Experiment
from repro.core.engine import validate_mesh_args
from repro.launch.mesh import make_local_mesh
from repro.sharding import build_rules, shard_act, sharding_ctx, spec_for

needs_multi = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >=2 jax devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _amesh(shape, names):
    # jax 0.4.37's AbstractMesh takes ((name, size), ...) pairs
    return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


# ---------------------------------------------------------------------------
# spec_for: size-1 mesh axes carry no parallelism — they must resolve
# to None WITHOUT being consumed (the (1,N)/(N,1) degenerate-mesh bug)
# ---------------------------------------------------------------------------

_CASES = [
    (("batch", "embed"), (16, 64)),
    (("embed", "heads"), (64, 8)),
    (("batch", "heads", "mlp"), (16, 8, 64)),
    (("expert", "embed", "mlp"), (8, 64, 32)),
    (("batch", "kv_seq"), (16, 256)),
]


@pytest.mark.parametrize("shape,names", [
    ((1, 4), ("data", "model")),
    ((4, 1), ("data", "model")),
    ((1, 8), ("data", "model")),
    ((8, 1), ("data", "model")),
    ((2, 2), ("data", "model")),
    ((1, 2, 4), ("pod", "data", "model")),
    ((2, 1, 4), ("pod", "data", "model")),
])
def test_spec_size1_axes_never_appear_never_consumed(shape, names):
    """Grid property over (1,N)/(N,1)/(2,2) meshes: no size-1 mesh axis
    ever appears in a produced spec, every appearing axis is unique,
    and every assignment divides its dimension."""
    mesh = _amesh(shape, names)
    rules = build_rules(mesh)
    sizes = dict(zip(names, shape))
    size1 = {a for a, n in sizes.items() if n == 1}
    for logical, dims in _CASES:
        s = spec_for(logical, dims, mesh, rules)
        flat = []
        for dim, assignment in zip(dims, tuple(s)):
            if assignment is None:
                continue
            axs = (assignment,) if isinstance(assignment, str) \
                else assignment
            flat.extend(axs)
            assert dim % int(np.prod([sizes[a] for a in axs])) == 0
        assert not (set(flat) & size1), (logical, s)
        assert len(flat) == len(set(flat)), (logical, s)


@pytest.mark.parametrize("deg_shape,deg_names,eff_shape,eff_names", [
    ((1, 8), ("data", "model"), (8,), ("model",)),
    ((8, 1), ("data", "model"), (8,), ("data",)),
    ((1, 1, 8), ("pod", "data", "model"), (8,), ("model",)),
])
def test_spec_degenerate_mesh_matches_reduced_mesh(
        deg_shape, deg_names, eff_shape, eff_names):
    """A mesh with size-1 axes must produce exactly the specs of the
    mesh with those axes removed — the regression that used to fail:
    the size-1 axis was assigned (``dim % 1 == 0``) and consumed."""
    deg = _amesh(deg_shape, deg_names)
    eff = _amesh(eff_shape, eff_names)
    dr, er = build_rules(deg), build_rules(eff)
    for logical, dims in _CASES:
        assert spec_for(logical, dims, deg, dr) == \
            spec_for(logical, dims, eff, er), (logical, dims)


def test_resolve_axes_contract():
    mesh = _amesh((1, 8), ("data", "model"))
    # size-1 mesh axes never shard
    assert SH.resolve_axes(mesh, "data", 8) is None
    # ...and are dropped from tuples, leaving the working suffix
    assert SH.resolve_axes(mesh, ("data", "model"), 16) == "model"
    # non-dividing -> replication fallback, never an error
    assert SH.resolve_axes(mesh, "model", 12) is None
    assert SH.resolve_axes(mesh, "model", 16) == "model"
    # no mesh / no request -> no placement
    assert SH.resolve_axes(None, "model", 16) is None
    assert SH.resolve_axes(mesh, None, 16) is None
    m22 = _amesh((2, 2), ("data", "model"))
    assert SH.resolve_axes(m22, ("data", "model"), 8) == ("data", "model")
    assert SH.resolve_axes(m22, ("data", "model"), 6) is None


# ---------------------------------------------------------------------------
# front-door validation
# ---------------------------------------------------------------------------

def test_make_local_mesh_too_few_devices_message():
    avail = len(jax.devices())
    with pytest.raises(ValueError) as ei:
        make_local_mesh(avail + 1, 2)
    msg = str(ei.value)
    assert f"needs {2 * (avail + 1)} device(s)" in msg
    assert f"only {avail} are available" in msg
    assert "xla_force_host_platform_device_count" in msg
    # the degenerate mesh is always constructible
    assert make_local_mesh(1, 1).size == 1


def test_validate_mesh_args_errors():
    mesh = _amesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="without a mesh"):
        validate_mesh_args(None, policy_axes="data")
    with pytest.raises(ValueError, match="only has"):
        validate_mesh_args(mesh, policy_axes="pod")
    with pytest.raises(ValueError, match="claimed by both"):
        validate_mesh_args(mesh, policy_axes="data", seed_axes="data")
    with pytest.raises(ValueError, match="wavefront"):
        validate_mesh_args(mesh, warp_axes="model", engine="event")
    validate_mesh_args(mesh, policy_axes="data", seed_axes="model")


def test_experiment_mesh_axes_without_mesh():
    with pytest.raises(ValueError, match="without a mesh"):
        registry.paper_fig7(("BFS",), name="x").with_(
            mesh_axes=("data", None, None))


# ---------------------------------------------------------------------------
# shard_act (1, N)-mesh regression: len(mesh.devices) measures only the
# first dimension of the device ndarray, so the pre-fix guard treated
# every (1, N) mesh as single-device and constraints silently no-opped
# ---------------------------------------------------------------------------

@needs_multi
def test_shard_act_constrains_on_1xN_mesh():
    n = len(jax.devices())
    mesh = make_local_mesh(1, n)                 # the (1, N) shape
    assert len(mesh.devices) == 1                # the measurement the
    assert mesh.size == n                        # old guard got wrong
    with sharding_ctx(mesh):
        f = jax.jit(lambda x: shard_act(x, "batch", "heads"))
        y = f(jnp.zeros((4, 8 * n)))
    # "heads" -> model must actually shard: pre-fix the constraint
    # no-opped and the output stayed on one device
    assert y.sharding.is_equivalent_to(
        jax.sharding.NamedSharding(mesh, P(None, "model")), 2)
    assert len(y.sharding.device_set) == n


# ---------------------------------------------------------------------------
# end-to-end parity: the sharded Experiment is bitwise-identical to the
# single-device one (golden suites pin the single-device numbers)
# ---------------------------------------------------------------------------

def _bitwise(rs_a, rs_b):
    assert rs_a.scenarios == rs_b.scenarios
    assert rs_a.policies == rs_b.policies
    for name in rs_a.scenarios:
        for seed in rs_a.seeds(name):
            ma = rs_a.get(name, seed=seed)
            mb = rs_b.get(name, seed=seed)
            assert set(ma) == set(mb)
            for k in ma:
                np.testing.assert_array_equal(
                    np.asarray(ma[k]), np.asarray(mb[k]),
                    err_msg=f"{name} seed={seed} metric={k}")


def _mesh2d():
    n = len(jax.devices())
    pow2 = 1 << (n.bit_length() - 1)
    return make_local_mesh(2, pow2 // 2) if pow2 >= 4 \
        else make_local_mesh(1, pow2)


@needs_multi
def test_event_sharded_parity_fig7_quick():
    exp = registry.paper_fig7(registry.QUICK_WORKLOADS, seeds=(0, 1),
                              name="parity_ev")
    sh = exp.with_(mesh=_mesh2d(), mesh_axes=("data", "model", None))
    _bitwise(exp.run(), sh.run())


@needs_multi
def test_wavefront_sharded_parity_phased48():
    exp = registry.phased(("PHASED48",), name="parity_wf")
    sh = exp.with_(mesh=_mesh2d(), mesh_axes=("data", None, "model"))
    call = sh.compile().calls[0]
    assert call.mesh is not None and call.warp_axes == "model"
    _bitwise(exp.run(), sh.run())


@needs_multi
def test_event_sharded_parity_phased48():
    exp = registry.phased(("PHASED48",), engine="event",
                          name="parity_ev48")
    sh = exp.with_(mesh=_mesh2d(), mesh_axes=("data", None, None))
    _bitwise(exp.run(), sh.run())


@needs_multi
def test_nondividing_axes_fall_back_to_replication():
    """3 policies on a 2-wide mesh axis, 1-entry seed stack: every
    placement resolves to None, the plan still runs, and results match
    the mesh-less run bitwise."""
    from repro.core import baselines as BL
    exp = Experiment("parity_fb",
                     registry.paper_fig7(("BFS",)).scenarios,
                     (BL.BASELINE, BL.PCAL, BL.MEDIC))
    sh = exp.with_(mesh=_mesh2d(), mesh_axes=("data", "model", None))
    call = sh.compile().calls[0]
    assert call.policy_axes is None and call.seed_axes is None
    _bitwise(exp.run(), sh.run())


# ---------------------------------------------------------------------------
# subprocess smoke: gives plain tier-1 (single-device) real multi-device
# coverage — XLA_FLAGS must be set before the first jax import, so this
# cannot be an in-process fixture
# ---------------------------------------------------------------------------

_SUBPROC = textwrap.dedent("""
    import os, re
    # the inherited env may already force a device count (e.g. the
    # 512-device dry-run suite exports XLA_FLAGS into the pytest
    # process) — strip it and put ours LAST so it wins
    flags = re.sub(r"--xla_force_host_platform_device_count=\\d+", "",
                   os.environ.get("XLA_FLAGS", ""))
    os.environ["XLA_FLAGS"] = (
        flags + " --xla_force_host_platform_device_count=8")
    import jax, numpy as np
    assert len(jax.devices()) == 8, jax.devices()
    from repro.api import registry
    from repro.core import baselines as BL
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(2, 4)
    ev = registry.paper_fig7(("BFS",), seeds=(0, 1), name="sm_ev").with_(
        policies=(BL.BASELINE, BL.PCAL, BL.WBYP, BL.MEDIC))
    wf = registry.phased(("PHASED48",), name="sm_wf")
    for exp, axes in ((ev, ("data", "model", None)),
                      (wf, ("data", None, "model"))):
        rs0 = exp.run()
        rs1 = exp.with_(mesh=mesh, mesh_axes=axes).run()
        for name in rs0.scenarios:
            for seed in rs0.seeds(name):
                a, b = rs0.get(name, seed=seed), rs1.get(name, seed=seed)
                for k in a:
                    assert np.array_equal(
                        np.asarray(a[k]), np.asarray(b[k]),
                        equal_nan=True), (exp.name, name, seed, k)
    print("SHARDED_PARITY_OK")
""")


def test_multi_device_parity_subprocess(tmp_path):
    script = tmp_path / "sharded_smoke.py"
    script.write_text(_SUBPROC)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), os.pardir, "src"),
         env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARDED_PARITY_OK" in out.stdout
