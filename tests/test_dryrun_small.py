"""Small-mesh dry-run machinery tests (the 512-device sweep itself runs via
``python -m repro.launch.dryrun``; these tests exercise the same builders on
the single real CPU device) + HLO analyzer unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, _shape_bytes_and_elems


def test_shape_bytes():
    b, e = _shape_bytes_and_elems("f32[128,64]{1,0}")
    assert e == 128 * 64 and b == 4 * e
    b, e = _shape_bytes_and_elems("(bf16[2,3]{1,0}, s32[])")
    assert e == 7 and b == 16


def test_analyzer_counts_scan_trips_and_dots():
    """A scanned matmul chain: rolled dot flops == unrolled hand count."""
    L, B, D = 8, 4, 32

    def layer(x, w):
        return jnp.tanh(x @ w), None

    def f(ws, x):
        x, _ = jax.lax.scan(layer, x, ws)
        return x.sum()

    ws = jnp.ones((L, D, D))
    x = jnp.ones((B, D))
    compiled = jax.jit(f).lower(ws, x).compile()
    s = analyze(compiled.as_text())
    expected = 2 * B * D * D * L
    assert s.n_while >= 1
    assert max(s.trip_counts) == L
    np.testing.assert_allclose(s.dot_flops, expected, rtol=0.01)


def test_analyzer_vs_cost_analysis_consistency():
    """Without loops, rolled dot flops ~= XLA's own flops count."""
    from repro.launch.dryrun import cost_analysis_dict
    a = jnp.ones((64, 128))
    b = jnp.ones((128, 96))
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    s = analyze(compiled.as_text())
    ca = cost_analysis_dict(compiled)
    np.testing.assert_allclose(s.dot_flops, ca["flops"], rtol=0.05)


def test_build_cell_lowers_on_tiny_config(monkeypatch):
    """End-to-end cell builder path on 1 device with a reduced config (the
    512-device meshes are exercised by the real dry-run)."""
    import repro.launch.dryrun as DR
    from repro.configs.base import get_config

    tiny = get_config("qwen3_1_7b").reduced(num_layers=2)
    monkeypatch.setattr(DR, "get_config", lambda a: tiny)
    monkeypatch.setattr(
        DR, "make_production_mesh",
        lambda multi_pod=False: __import__(
            "repro.launch.mesh", fromlist=["x"]).make_local_mesh(1, 1))
    # shrink the shape so CPU compile stays fast
    import dataclasses
    from repro.configs.base import ShapeConfig
    monkeypatch.setitem(DR.SHAPES, "train_4k",
                        ShapeConfig("train_4k", 64, 4, "train"))
    res = DR.run_cell("qwen3_1_7b", "train_4k", multi_pod=False)
    assert res["status"] == "ok"
    assert res["hlo"]["dot_flops_per_dev"] > 0
    assert res["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_dryrun_results_if_present():
    """When the real sweep has produced results, validate the contract:
    every non-skipped cell compiled, and long_500k skips match DESIGN."""
    import glob
    import json
    import os
    files = glob.glob("results/dryrun/*.json")
    if not files:
        pytest.skip("512-device sweep not run in this environment")
    bad = []
    for fp in files:
        with open(fp) as f:
            d = json.load(f)
        if d["status"] == "error":
            bad.append((os.path.basename(fp), d.get("error", "")[:80]))
    assert not bad, bad
