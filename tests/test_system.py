"""End-to-end behaviour tests for the full MeDiC-JAX system."""
import jax
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import build_model
from repro.optim.optimizer import init_opt_state, make_train_step


def test_e2e_training_reduces_loss():
    """Deliverable (b): train a small model end-to-end, loss must drop."""
    cfg = get_config("qwen3_1_7b").reduced(num_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=5, total_steps=60)
    opt = init_opt_state(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=8, n_chains=1))
    it = ds.iterator()
    losses = []
    for _ in range(40):
        params, opt, m = step(params, opt, next(it))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_e2e_simulator_full_medic_stack():
    """Paper pipeline: workload -> simulator -> MeDiC beats baseline."""
    import jax.numpy as jnp
    from repro.core import baselines as BL
    from repro.core import workloads as WL
    from repro.core.simulator import SimParams, simulate
    spec = WL.WORKLOADS["SSSP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr,
              prm=SimParams())
    ipc_base = float(simulate(*args, pol=BL.BASELINE, **kw)["ipc"])
    ipc_medic = float(simulate(*args, pol=BL.MEDIC, **kw)["ipc"])
    assert ipc_medic > 1.05 * ipc_base
