"""Per-architecture smoke tests (reduced configs) + model invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, ShapeConfig, get_config, \
    shape_applicable
from repro.models.model import build_model

TINY_PREFILL = ShapeConfig("tiny_prefill", 32, 2, "prefill")


def _batch(cfg, rng, b=2, s=32):
    batch = {"tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            rng, (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one forward/train step on CPU with
    shape + finiteness assertions (deliverable f)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init_params(rng)
    batch = _batch(cfg, rng)
    cache = model.init_cache(2, TINY_PREFILL)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(model.decode)(params, tok, cache)
    assert bool(jnp.isfinite(logits2).all())
    assert int(cache2["len"][0]) == int(cache["len"][0]) + 1


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "h2o_danube_1_8b",
                                  "recurrentgemma_2b", "xlstm_125m"])
def test_decode_matches_full_forward(arch):
    """KV-cached decode logits == running the full sequence (teacher
    forcing) — the cache-correctness invariant."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init_params(rng)
    s = 16
    toks = jax.random.randint(rng, (1, s), 0, cfg.vocab_size)
    batch = {"tokens": toks}

    # cache must have capacity for the decoded token too (s+1 slots),
    # otherwise the ring legitimately drops position 0
    shape = ShapeConfig("t", s + 1, 1, "prefill")
    cache = model.init_cache(1, shape)
    logits_prefill, cache = model.prefill(params, batch, cache)

    # decode one token and compare against prefilling s+1 tokens
    nxt = jnp.asarray([[7]], jnp.int32)
    logits_decode, _ = model.decode(params, nxt, cache)

    toks2 = jnp.concatenate([toks, nxt], axis=1)
    shape2 = ShapeConfig("t2", s + 1, 1, "prefill")
    cache2 = model.init_cache(1, shape2)
    logits_ref, _ = model.prefill(params, {"tokens": toks2}, cache2)

    np.testing.assert_allclose(np.asarray(logits_decode, np.float32),
                               np.asarray(logits_ref, np.float32),
                               rtol=0.08, atol=0.08)


def test_causality_property():
    """Changing a future token must not change past logits (dense arch)."""
    cfg = get_config("granite_3_8b").reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init_params(rng)
    toks = jax.random.randint(rng, (1, 16), 0, cfg.vocab_size)

    def logits_at(tokens, pos):
        # reuse loss machinery's forward: prefill returns last-pos only, so
        # run through loss-style full logits via model internals
        aux = model._aux_for(params, {"tokens": tokens}, "train")
        x = model._embed(params, tokens)
        from repro.models.stack import apply_stack
        x, _, _ = apply_stack(cfg, model.stack, params["stack"], x, aux)
        return model._head(params, x)[0, pos]

    base = logits_at(toks, 5)
    toks2 = toks.at[0, 10].set((int(toks[0, 10]) + 3) % cfg.vocab_size)
    pert = logits_at(toks2, 5)
    np.testing.assert_allclose(np.asarray(base), np.asarray(pert),
                               rtol=1e-5, atol=1e-5)


def test_sliding_window_attention_bounds_context():
    """With SWA, a token far outside the window has no influence."""
    cfg = get_config("h2o_danube_1_8b").reduced(sliding_window=8)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(4)
    params = model.init_params(rng)
    toks = jax.random.randint(rng, (1, 32), 0, cfg.vocab_size)

    def last_logits(tokens):
        aux = model._aux_for(params, {"tokens": tokens}, "train")
        x = model._embed(params, tokens)
        from repro.models.stack import apply_stack
        x, _, _ = apply_stack(cfg, model.stack, params["stack"], x, aux)
        return model._head(params, x)[0, -1]

    base = last_logits(toks)
    # layers stack windows: influence horizon = num_layers * window; token 0
    # is outside it for 4 layers * 8 = 32 > 31... use a 1-layer variant
    cfg1 = get_config("h2o_danube_1_8b").reduced(sliding_window=8,
                                                 num_layers=1)
    model1 = build_model(cfg1)
    params1 = model1.init_params(rng)

    def last1(tokens):
        aux = model1._aux_for(params1, {"tokens": tokens}, "train")
        x = model1._embed(params1, tokens)
        from repro.models.stack import apply_stack
        x, _, _ = apply_stack(cfg1, model1.stack, params1["stack"], x, aux)
        return model1._head(params1, x)[0, -1]

    b1 = last1(toks)
    toks2 = toks.at[0, 2].set((int(toks[0, 2]) + 5) % cfg1.vocab_size)
    b2 = last1(toks2)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), rtol=1e-5,
                               atol=1e-5)


def test_long_500k_applicability_matrix():
    """Exactly the sub-quadratic archs run long_500k (DESIGN.md §5)."""
    runs = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
            for a in ARCH_IDS}
    assert runs == {
        "grok_1_314b": False, "olmoe_1b_7b": False,
        "recurrentgemma_2b": True, "h2o_danube_1_8b": True,
        "qwen1_5_110b": False, "qwen3_1_7b": False, "granite_3_8b": False,
        "whisper_tiny": False, "llama_3_2_vision_11b": False,
        "xlstm_125m": True,
    }


def test_param_counts_in_expected_range():
    """Full configs land near their nameplate sizes."""
    expected = {
        "grok_1_314b": (280e9, 340e9),
        "qwen1_5_110b": (95e9, 120e9),
        "olmoe_1b_7b": (6e9, 8e9),
        "qwen3_1_7b": (1.4e9, 2.2e9),
        "granite_3_8b": (6.5e9, 9.5e9),
        "h2o_danube_1_8b": (1.4e9, 2.2e9),
        # 256k-vocab embed + head (untied) put rgemma above its nameplate
        "recurrentgemma_2b": (2.2e9, 3.8e9),
        "xlstm_125m": (0.10e9, 0.22e9),
        "whisper_tiny": (0.02e9, 0.08e9),
        "llama_3_2_vision_11b": (8e9, 12e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).num_params
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e},{hi:.1e}]"
