"""Serving engine + MeDiC pool manager (altitude B) tests."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import warp_types as WT
from repro.serving.engine import EngineConfig, ServeEngine, run_ab
from repro.serving.pool import MedicPoolManager, PoolConfig
from repro.serving.request import ServeWorkload, generate_requests


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("qwen3_1_7b").reduced(num_layers=2)


def test_pool_classifier_separates_hot_and_cold():
    pool = MedicPoolManager(PoolConfig(budget_blocks=8, sampling_interval=8),
                            max_seqs=4)
    for step in range(20):
        pool.access(0, [0, 1], float(step))          # hot: 2 blocks, reused
        pool.access(1, [step * 4 + i for i in range(4)], float(step))
    assert int(pool.seq_type[0]) >= WT.MOSTLY_HIT
    assert int(pool.seq_type[1]) <= WT.MOSTLY_MISS


def test_pool_bypass_not_retained():
    cfg = PoolConfig(budget_blocks=4, sampling_interval=4, policy="medic")
    pool = MedicPoolManager(cfg, max_seqs=2)
    # make slot 0 mostly-miss first
    for step in range(12):
        pool.access(0, [step * 4 + i for i in range(4)], float(step))
    assert int(pool.seq_type[0]) <= WT.MOSTLY_MISS
    before = len(pool.resident)
    pool.access(0, [999], 100.0)
    # bypassed: not retained
    assert (0, 999) not in pool.resident
    assert pool.bypassed_blocks > 0


def test_pool_two_queue_priority():
    cfg = PoolConfig(budget_blocks=2, sampling_interval=4,
                     fetch_occupancy=5.0, policy="medic")
    pool = MedicPoolManager(cfg, max_seqs=4)
    pool.seq_type[0] = WT.MOSTLY_HIT
    pool.seq_type[1] = WT.MOSTLY_MISS
    # pile low-priority fetches, then a high-priority one at the same time
    pool.access(1, [10, 11, 12, 13], 0.0)
    t_hp, _ = pool.access(0, [99], 0.0)
    # high-priority fetch is NOT stuck behind the lp backlog
    assert t_hp <= cfg.fetch_latency + cfg.fetch_occupancy + 1e-6


def test_engine_outputs_identical_under_tight_budget(tiny_cfg):
    """Residency management moves real data: a tight-budget MeDiC run must
    produce the same number of tokens per request as an unconstrained run
    and never corrupt state (same completion set)."""
    wl = ServeWorkload(n_requests=8, arrival_rate=4.0)
    big = PoolConfig(budget_blocks=4096, block_tokens=16)
    small = PoolConfig(budget_blocks=32, block_tokens=16)
    e1 = ServeEngine(tiny_cfg, EngineConfig(max_slots=2, max_len=448), big)
    r1 = e1.run(generate_requests(wl, seed=1), max_steps=1500)
    e2 = ServeEngine(tiny_cfg, EngineConfig(max_slots=2, max_len=448), small)
    r2 = e2.run(generate_requests(wl, seed=1), max_steps=4000)
    assert r1["completed"] == 8
    assert r2["completed"] == 8
    # constrained run pays stalls, not correctness
    assert r2["stall_steps"] >= r1["stall_steps"]


def test_engine_medic_beats_lru_under_pressure(tiny_cfg):
    wl = ServeWorkload(n_requests=16, arrival_rate=4.0)
    pool = PoolConfig(budget_blocks=40, block_tokens=16)
    out = run_ab(tiny_cfg, wl, pool, EngineConfig(max_slots=4, max_len=448),
                 seed=0)
    assert out["medic"]["throughput"] > 1.2 * out["lru"]["throughput"]


def test_engine_hit_ratio_heterogeneity(tiny_cfg):
    """Chat (shared-prefix) sequences classify hotter than RAG ones."""
    wl = ServeWorkload(n_requests=12, chat_frac=0.5, arrival_rate=4.0)
    pool = PoolConfig(budget_blocks=48, block_tokens=16)
    eng = ServeEngine(tiny_cfg, EngineConfig(max_slots=4, max_len=448), pool)
    reqs = generate_requests(wl, seed=2)
    eng.run(reqs, max_steps=1200)
    snap = eng.pool.snapshot()
    ratios = snap["seq_hit_ratio"][:4]
    assert np.nanmax(ratios) > 0.7  # someone is hot
