"""Differential tests: vectorized sampler vs loop reference, bit-exact.

The tracegen contract (ISSUE 2): for every workload in ``WL.WORKLOADS``
and every seed, ``sampler.generate`` and ``ref.generate_ref`` produce
IDENTICAL arrays — not statistically close, equal. The counter RNG makes
this well-defined; these tests enforce it, plus the scalar/array RNG
mirror equality it rests on, plus the batch-stacking and sweep-feeding
contracts.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import tracegen as TG
from repro.core import workloads as WL
from repro.core.simulator import SimParams, simulate_sweep
from repro.core.tracegen import rng

DIFF_SEEDS = (0, 1, 2)


# ---------------------------------------------------------------------------
# scalar RNG mirror == array RNG (the dual implementation under the diff)
# ---------------------------------------------------------------------------

def test_rng_scalar_matches_array():
    probe = np.random.default_rng(7).integers(
        0, 1 << 63, size=256).astype(np.uint64)
    idx = np.arange(256, dtype=np.uint64)
    assert np.array_equal(rng.mix64(probe),
                          [rng.mix64_scalar(int(x)) for x in probe])
    key = rng.stream_key(np.uint64(12345), rng.TAG_REUSE_U)
    assert int(key) == rng.stream_key_scalar(12345, rng.TAG_REUSE_U)
    assert np.array_equal(rng.bits(key, idx),
                          [rng.bits_scalar(int(key), i) for i in range(256)])
    assert np.array_equal(rng.uniform(key, idx),
                          [rng.uniform_scalar(int(key), i)
                           for i in range(256)])
    assert np.array_equal(rng.randint(key, idx, 97),
                          [rng.randint_scalar(int(key), i, 97)
                           for i in range(256)])


def test_perm12_is_a_permutation_and_matches_scalar():
    j = np.arange(4096, dtype=np.uint64)
    for key in (np.uint64(1), np.uint64(0xDEADBEEFCAFE)):
        p = rng.perm12(j, key)
        assert sorted(p.tolist()) == list(range(4096))
        sample = [0, 1, 63, 64, 4095]
        assert [rng.perm12_scalar(s, int(key)) for s in sample] == \
            [int(p[s]) for s in sample]


# ---------------------------------------------------------------------------
# the differential: every workload x 3 seeds, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", WL.WORKLOAD_NAMES)
def test_vectorized_matches_loop_ref(workload):
    spec = TG.TraceSpec.from_workload(WL.WORKLOADS[workload])
    for seed in DIFF_SEEDS:
        vec = TG.generate(spec, seed)
        ref = TG.generate_ref(spec, seed)
        for key in ("lines", "pcs", "archetype", "archetype2"):
            assert np.array_equal(vec[key], ref[key]), (workload, seed, key)
        assert vec["compute_gap"] == ref["compute_gap"]
        assert vec["lines"].dtype == np.int32
        assert vec["pcs"].dtype == np.int32


def test_workloads_generate_is_the_vectorized_path():
    spec = WL.WORKLOADS["MST"]
    a = WL.generate(spec, seed=5)
    b = TG.generate(TG.TraceSpec.from_workload(spec), seed=5)
    for key in ("lines", "pcs", "archetype"):
        assert np.array_equal(a[key], b[key])


def test_stress_spec_matches_loop_ref_small():
    """The loop ref also agrees on non-default spec knobs (boosted shared
    fractions, aggressive phase shifts) — shrunk to keep the loop fast."""
    for name, spec in TG.STRESS_SPECS.items():
        small = dataclasses.replace(spec, n_warps=32, n_instr=16)
        vec = TG.generate(small, 1)
        ref = TG.generate_ref(small, 1)
        for key in ("lines", "pcs", "archetype", "archetype2"):
            assert np.array_equal(vec[key], ref[key]), (name, key)


# ---------------------------------------------------------------------------
# batch stacking + feeding simulate_sweep
# ---------------------------------------------------------------------------

def test_generate_batch_matches_singles():
    specs = [TG.TraceSpec.from_workload(WL.WORKLOADS[w])
             for w in ("BFS", "BP")]
    seeds = (0, 3)
    batch = TG.generate_batch(specs, seeds)
    assert batch["lines"].shape[:2] == (2, 2)
    assert batch["compute_gap"].shape == (2, 2)
    for ni, spec in enumerate(specs):
        for si, seed in enumerate(seeds):
            one = TG.generate(spec, seed)
            for key in ("lines", "pcs", "archetype"):
                assert np.array_equal(batch[key][ni, si], one[key]), \
                    (spec.name, seed, key)
            assert batch["compute_gap"][ni, si] == one["compute_gap"]


def test_workloads_generate_suite_wraps_batch():
    suite = WL.generate_suite(("BFS", "BP"), seeds=(0, 1))
    specs = [TG.TraceSpec.from_workload(WL.WORKLOADS[w])
             for w in ("BFS", "BP")]
    batch = TG.generate_batch(specs, (0, 1))
    for key in ("lines", "pcs", "archetype", "compute_gap"):
        assert np.array_equal(suite[key], batch[key])


def test_spec_validation_guards():
    base = TG.TraceSpec.from_workload(WL.WORKLOADS["BFS"])
    # mix must sum to 1 (the legacy default_rng.choice(p=...) check)
    bad_mix = dataclasses.replace(base, mix=(0.5, 0.2, 0.1, 0.1, 0.05))
    with pytest.raises(ValueError, match="mix sums"):
        TG.generate(bad_mix, 0)
    # working sets larger than perm12's bijection domain must not
    # silently produce duplicate lines
    big_ws = dataclasses.replace(
        base, archetypes=((8192, 0.9, 0.0),) * 5)
    with pytest.raises(ValueError, match="choice domain"):
        TG.generate(big_ws, 0)


def test_generate_batch_rejects_mixed_shapes():
    a = TG.TraceSpec.from_workload(WL.WORKLOADS["BFS"])
    b = dataclasses.replace(a, name="wide", n_warps=64)
    with pytest.raises(ValueError, match="heterogeneous"):
        TG.generate_batch([a, b], seeds=(0,))


def test_batch_feeds_simulate_sweep_as_one_call():
    """workloads x seeds collapse onto simulate_sweep's seed axis: one
    jitted call sweeps policies x seeds x workloads, and each column
    equals the corresponding single-trace sweep."""
    prm = SimParams()
    names = ("BFS", "BP")
    specs = [TG.TraceSpec.from_workload(WL.WORKLOADS[w]) for w in names]
    seeds = (0, 1)
    batch = TG.generate_batch(specs, seeds)
    n, s, i, w, l = batch["lines"].shape
    pols = (BL.BASELINE, BL.MEDIC)
    out = simulate_sweep(
        jnp.asarray(batch["lines"].reshape(n * s, i, w, l)),
        jnp.asarray(batch["pcs"].reshape(n * s, i, w)),
        jnp.asarray(batch["compute_gap"].reshape(n * s)),
        pols, n_warps=w, lanes=l, prm=prm)
    assert out["ipc"].shape == (len(pols), n * s)
    # column (workload 1, seed 0) == unstacked sweep of that trace
    tr = TG.generate(specs[1], seeds[0])
    flat = simulate_sweep(jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                          jnp.asarray(tr["compute_gap"]), pols,
                          n_warps=w, lanes=l, prm=prm)
    assert np.array_equal(np.asarray(out["ipc"][:, 1 * s + 0]),
                          np.asarray(flat["ipc"]))
