"""Behavioural tests for the altitude-A MeDiC simulator — including the
paper-claim validations (orderings from Fig 7, heterogeneity from Fig 2,
stability from Fig 4, queueing from Fig 5)."""
import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import workloads as WL
from repro.core.simulator import SimParams, simulate

PRM = SimParams()


@functools.lru_cache(maxsize=64)
def run(workload: str, policy_name: str, seed: int = 0):
    spec = WL.WORKLOADS[workload]
    tr = WL.generate(spec, seed=seed)
    pol = {p.name: p for p in BL.ALL_NAMED}.get(policy_name) or BL.rand(0.5)
    out = simulate(jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                   jnp.asarray(tr["compute_gap"]), n_warps=spec.n_warps,
                   lanes=spec.lines_per_instr, prm=PRM, pol=pol)
    return {k: np.asarray(v) for k, v in out.items()}, tr


def test_counts_consistent():
    out, tr = run("BFS", "Baseline")
    total_requests = int((tr["lines"] >= 0).sum())
    assert int(out["l2_accesses"]) + int(out["bypasses"]) == total_requests
    assert int(out["l2_hits"]) <= int(out["l2_accesses"])
    # every miss and every bypass goes to DRAM
    assert int(out["dram_accesses"]) == total_requests - int(out["l2_hits"])


def test_fig2_heterogeneity_spectrum():
    """Warps must span the full hit-ratio range under the baseline."""
    out, tr = run("BFS", "Baseline")
    hr = out["warp_hit_ratio"]
    assert hr.min() < 0.05
    assert hr.max() > 0.9
    assert 0.1 < np.median(hr) < 0.9 or (hr > 0.5).any()


def test_fig4_stability_over_time():
    """A warp's sampled ratio should correlate strongly between the two
    halves of the kernel (temporal stability, no phase-shift workload)."""
    out, tr = run("BFS", "Baseline")
    rt = out["ratio_over_time"]          # [I, W]
    half = rt.shape[0] // 2
    a = rt[half - 8:half].mean(axis=0)
    b = rt[-8:].mean(axis=0)
    mask = (a > 0) | (b > 0)
    corr = np.corrcoef(a[mask], b[mask])[0, 1]
    assert corr > 0.8, corr


def test_fig5_queueing_latencies_heavy_tail():
    """Intensive workloads see queuing delays of tens-to-hundreds of
    cycles at the shared cache (paper observation O3)."""
    out, _ = run("BFS", "Baseline")
    hist = out["qdelay_hist"]
    # bins: 0,1,2,4,...,1024+ ; some requests must wait >= 64 cycles
    assert hist[7:].sum() > 0
    assert float(out["mean_qdelay"]) > 10.0


def test_bypass_reduces_l2_load_and_miss_rate():
    base, _ = run("BFS", "Baseline")
    wbyp, _ = run("BFS", "WByp")
    assert int(wbyp["bypasses"]) > 0
    assert int(wbyp["l2_accesses"]) < int(base["l2_accesses"])
    # bypassing miss-class warps leaves hit-heavy traffic at the L2
    assert float(wbyp["miss_rate"]) < float(base["miss_rate"])


def test_wip_protects_hot_warps():
    base, tr = run("BFS", "Baseline")
    wip, _ = run("BFS", "WIP")
    hot = tr["archetype"] <= 1  # all_hit + mostly_hit archetypes
    assert wip["warp_hit_ratio"][hot].mean() > \
        base["warp_hit_ratio"][hot].mean()


def test_medic_converts_warp_types():
    """mostly-hit -> higher ratio; mostly-miss -> all-miss (paper goal)."""
    base, tr = run("BFS", "Baseline")
    medic, _ = run("BFS", "MeDiC")
    mh = tr["archetype"] == 1
    mm = tr["archetype"] == 3
    assert medic["warp_hit_ratio"][mh].mean() > \
        base["warp_hit_ratio"][mh].mean()
    assert medic["warp_hit_ratio"][mm].mean() < 0.1


@pytest.mark.parametrize("workload", ["BFS", "SSSP", "CONS"])
def test_fig7_orderings(workload):
    """Key orderings from the paper's evaluation on intensive workloads:
    MeDiC > Baseline, MeDiC >= WByp, WByp > PCAL, MeDiC > PC-Byp."""
    base, _ = run(workload, "Baseline")
    medic, _ = run(workload, "MeDiC")
    wbyp, _ = run(workload, "WByp")
    pcal, _ = run(workload, "PCAL")
    pcbyp, _ = run(workload, "PC-Byp")
    b = float(base["ipc"])
    assert float(medic["ipc"]) > 1.05 * b
    assert float(medic["ipc"]) >= 0.98 * float(wbyp["ipc"])
    assert float(wbyp["ipc"]) > float(pcal["ipc"])
    assert float(medic["ipc"]) > float(pcbyp["ipc"])


def test_fig8_energy_efficiency():
    base, _ = run("BFS", "Baseline")
    medic, _ = run("BFS", "MeDiC")
    assert float(medic["perf_per_energy"]) > float(base["perf_per_energy"])


def test_determinism():
    a, _ = run("MST", "MeDiC", seed=3)
    spec = WL.WORKLOADS["MST"]
    tr = WL.generate(spec, seed=3)
    out = simulate(jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                   jnp.asarray(tr["compute_gap"]), n_warps=spec.n_warps,
                   lanes=spec.lines_per_instr, prm=PRM, pol=BL.MEDIC)
    assert float(out["ipc"]) == pytest.approx(float(a["ipc"]))


def test_wms_prioritizes_hot_misses():
    """With the full MeDiC bypass load, the two-queue scheduler must not
    slow hot warps; their mean round time should improve vs no-WMS."""
    medic, tr = run("SSSP", "MeDiC")
    # WByp+WIP without WMS
    spec = WL.WORKLOADS["SSSP"]
    from repro.core.simulator import Policy
    nowms = Policy("nowms", bypass="medic", insertion="medic")
    out = simulate(jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                   jnp.asarray(tr["compute_gap"]), n_warps=spec.n_warps,
                   lanes=spec.lines_per_instr, prm=PRM, pol=nowms)
    hot = tr["archetype"] <= 1
    t_with = np.asarray(medic["warp_time"])[hot].mean()
    t_without = np.asarray(out["warp_time"])[hot].mean()
    assert t_with <= 1.05 * t_without
