"""Checkpointing (atomic/async/restore/reshard) + fault-tolerant loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointing import CheckpointManager
from repro.configs.base import OptimizerConfig, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import build_model
from repro.optim.optimizer import init_opt_state, make_train_step
from repro.runtime.fault_tolerance import (FailureInjector, StragglerDetector,
                                           run_fault_tolerant)


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_config("qwen3_1_7b").reduced(num_layers=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-2, warmup_steps=5, total_steps=100)
    opt = init_opt_state(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                global_batch=4, n_chains=1))
    return cfg, model, params, ocfg, opt, step, ds


def test_checkpoint_roundtrip_bf16(tmp_path, small_setup):
    _, _, params, _, opt, _, _ = small_setup
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    ck.save(3, {"params": params, "opt": opt}, {"data": {"step": 3}})
    out = ck.restore_latest({"params": params, "opt": opt})
    assert out is not None
    step, tree, extra = out
    assert step == 3 and extra["data"]["step"] == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree["params"])):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_atomicity(tmp_path, small_setup):
    _, _, params, _, opt, _, _ = small_setup
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, {"params": params, "opt": opt})
    assert ck.all_steps() == [3, 4]
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_async_save(tmp_path, small_setup):
    _, _, params, _, opt, _, _ = small_setup
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    ck.save(7, {"params": params, "opt": opt})
    ck.wait()
    assert ck.latest_step() == 7


def test_restart_resume_bitwise_identical(tmp_path, small_setup):
    """A run with injected failures must produce the same final loss as an
    uninterrupted run (checkpoint/restart correctness)."""
    _, _, params, ocfg, opt, step, ds = small_setup

    ck1 = CheckpointManager(str(tmp_path / "a"), keep=3, async_save=False)
    r1 = run_fault_tolerant(step, params, opt, ds.iterator(), ckpt=ck1,
                            total_steps=12, checkpoint_every=4,
                            injector=FailureInjector(fail_at=(6,)))
    ck2 = CheckpointManager(str(tmp_path / "b"), keep=3, async_save=False)
    r2 = run_fault_tolerant(step, params, opt, ds.iterator(), ckpt=ck2,
                            total_steps=12, checkpoint_every=4)
    assert r1.restarts == 1 and r2.restarts == 0
    l1 = r1.metrics_history[-1]["loss"]
    l2 = r2.metrics_history[-1]["loss"]
    assert l1 == pytest.approx(l2, rel=1e-6)


def test_elastic_reshard_between_meshes(tmp_path, small_setup):
    """Save on one 'mesh', restore onto a different sharding layout
    (elastic re-scale path; single device here, shardings still differ)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import single_device_mesh
    _, _, params, _, opt, _, _ = small_setup
    ck = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    ck.save(1, {"params": params})
    mesh = single_device_mesh()
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), {"params": params})
    step, tree, _ = ck.restore_latest({"params": params}, shardings)
    leaf = jax.tree.leaves(tree["params"])[0]
    assert leaf.sharding == NamedSharding(mesh, P())


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(window=10, threshold=3.0)
    hits = []
    for i in range(30):
        dt = 1.0 if i != 25 else 8.0
        det.observe(i, dt, mitigate=lambda s: hits.append(s))
    assert any(e["step"] == 25 for e in det.events)
    assert hits == [25]


def test_data_pipeline_determinism_and_resume():
    ds = SyntheticLM(DataConfig(vocab_size=128, seq_len=16, global_batch=4))
    it = ds.iterator()
    batches = [next(it) for _ in range(5)]
    state = it.state_dict()
    it2 = ds.iterator()
    it2.load_state_dict(state)
    np.testing.assert_array_equal(next(it2)["tokens"],
                                  ds.get_batch(5)["tokens"])
    np.testing.assert_array_equal(batches[2]["tokens"],
                                  ds.get_batch(2)["tokens"])


def test_grad_compression_int8_close_to_exact(small_setup):
    """int8-with-error-feedback training should track exact training."""
    cfg, model, params, _, _, _, ds = small_setup
    o1 = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=30)
    o2 = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=30,
                         grad_compression="int8")
    s1 = jax.jit(make_train_step(model, o1))
    s2 = jax.jit(make_train_step(model, o2))
    p1 = p2 = params
    st1 = init_opt_state(params, o1)
    st2 = init_opt_state(params, o2)
    it = ds.iterator()
    for _ in range(10):
        b = next(it)
        p1, st1, m1 = s1(p1, st1, b)
        p2, st2, m2 = s2(p2, st2, b)
    assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=0.05)


def test_compressed_psum_matches_psum():
    from repro.optim.optimizer import compressed_psum
    from repro.sharding import single_device_mesh
    import jax
    # jax.shard_map is top-level only from 0.6; on the pinned 0.4.x
    # runtime it lives in jax.experimental.shard_map
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map
    mesh = single_device_mesh()
    x = jnp.asarray(np.random.default_rng(0).standard_normal((64,)),
                    jnp.float32)

    def f(v):
        return compressed_psum(v, "data")

    y = jax.jit(shard_map(
        f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec()))(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.02)
