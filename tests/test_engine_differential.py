"""Differential suite: wavefront engine vs the exact event engine.

Three rungs, mirroring the repo's other ref-vs-vectorized pairs
(`pool_ref`, `tracegen/ref.py`):

  1. single-warp traces — EXACT parity (a wave of one warp reduces every
     prefix op to the event engine's scalar update);
  2. ``wave_size=1`` at paper scale — exact parity (the wave machinery
     with chronological selection IS the event loop);
  3. default wave size at paper scale — documented tolerance: ≤2% on
     IPC/makespan and identical Fig 7 policy ordering, across all 15
     workloads (DESIGN.md §9 accuracy envelope).

Plus the batched-classifier property tests the wavefront engine relies
on: an [N]-shaped ``classifier.observe`` with distinct warp ids must
equal N sequential scalar observes, window resets included.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import classifier as CLF
from repro.core import tracegen as TG
from repro.core import workloads as WL
from repro.core.simulator import SimParams, simulate, simulate_sweep
from repro.policy import to_arrays

PRM = SimParams()
# one policy per mechanism family, matching the stress-matrix sweep
DIFF_POLICIES = (BL.BASELINE, BL.PCAL, BL.WBYP, BL.MEDIC)
#: default labeling/window knobs — what the pre-phased engines ran with
PA_DEFAULT = to_arrays(BL.BASELINE)

INT_KEYS = ("l2_accesses", "l2_hits", "dram_accesses", "row_hits",
            "bypasses", "qdelay_hist", "evictions_by_type")


def _run_pair(trace, n_warps, lanes, policies, **wf_kw):
    args = (jnp.asarray(trace["lines"]), jnp.asarray(trace["pcs"]),
            jnp.asarray(trace["compute_gap"]))
    kw = dict(n_warps=n_warps, lanes=lanes, prm=PRM)
    if "oracle_wtype" in trace:
        kw["oracle_types"] = jnp.asarray(trace["oracle_wtype"])
    ev = simulate_sweep(*args, policies, engine="event", **kw)
    wf = simulate_sweep(*args, policies, engine="wavefront", **kw, **wf_kw)
    tonp = lambda d: {k: np.asarray(v) for k, v in d.items()}
    return tonp(ev), tonp(wf)


# ---------------------------------------------------------------------------
# rung 1: single-warp traces are exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["BFS", "BP"])
def test_single_warp_exact(workload):
    spec = dataclasses.replace(
        TG.TraceSpec.from_workload(WL.WORKLOADS[workload]), n_warps=1)
    tr = TG.generate(spec, seed=0)
    ev, wf = _run_pair(tr, 1, spec.lines_per_instr, DIFF_POLICIES)
    for k in INT_KEYS:
        assert np.array_equal(ev[k], wf[k]), k
    for k in ("makespan", "ipc", "stall_cycles", "qdelay_sum",
              "warp_hit_ratio", "ratio_over_time"):
        np.testing.assert_allclose(wf[k], ev[k], rtol=1e-5, atol=1e-5,
                                   err_msg=k)


# ---------------------------------------------------------------------------
# rung 2: wave_size=1 IS the event loop
# ---------------------------------------------------------------------------

def test_wave_of_one_matches_event_at_paper_scale():
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    ev, wf = _run_pair(tr, spec.n_warps, spec.lines_per_instr,
                       (BL.BASELINE, BL.MEDIC), wave_size=1)
    for k in ev:
        np.testing.assert_allclose(wf[k], ev[k], rtol=1e-5, atol=1e-5,
                                   err_msg=k)


# ---------------------------------------------------------------------------
# rung 3: default wave size, tolerance + ordering across all 15 workloads
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _pair_48(workload: str):
    spec = WL.WORKLOADS[workload]
    tr = WL.generate(spec, seed=0)
    return _run_pair(tr, spec.n_warps, spec.lines_per_instr, DIFF_POLICIES)


@pytest.mark.parametrize("workload", WL.WORKLOAD_NAMES)
def test_tolerance_and_ordering_at_48_warps(workload):
    """Measured accuracy envelope at the default wave size (W//6):
    worst |IPC| 1.9% and worst makespan 4.2% over the 15-workload ×
    4-policy matrix (DESIGN.md §9) — asserted at 2% / 4.5%. The
    makespan envelope was re-measured for PR 7: the probe-ratchet fix
    makes labels responsive to the probe sample, so a single warp whose
    window closes on different wave boundaries can relabel a wave apart
    between engines and finish visibly later — makespan (a max, not a
    mean) sees it undamped. One cell (NW × MeDiC, 4.2%) sits past the
    old 2.5% bound; the next-worst cell is 2.0%."""
    ev, wf = _pair_48(workload)
    ipc_rel = np.abs(wf["ipc"] - ev["ipc"]) / ev["ipc"]
    mk_rel = np.abs(wf["makespan"] - ev["makespan"]) / ev["makespan"]
    assert ipc_rel.max() <= 0.02, (workload, ipc_rel)
    assert mk_rel.max() <= 0.045, (workload, mk_rel)
    # identical Fig 7 policy ordering
    assert np.array_equal(np.argsort(wf["ipc"]), np.argsort(ev["ipc"])), \
        (workload, wf["ipc"], ev["ipc"])


def test_aggregate_counters_close_at_48_warps():
    """Decision-dependent counters may drift slightly with ordering, but
    totals must stay conserved and close."""
    ev, wf = _pair_48("BFS")
    total = ev["l2_accesses"] + ev["bypasses"]
    assert np.array_equal(total, wf["l2_accesses"] + wf["bypasses"])
    for k in ("l2_hits", "dram_accesses"):
        np.testing.assert_allclose(wf[k], ev[k], rtol=0.02, err_msg=k)


def test_wavefront_sweep_matches_per_policy_bitwise():
    """The vmapped wavefront sweep must equal per-policy wavefront
    `simulate` calls bit-for-bit, mirroring the event-engine guarantee
    in tests/test_policy_engine.py."""
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM,
              engine="wavefront")
    sweep = {k: np.asarray(v) for k, v in
             simulate_sweep(*args, DIFF_POLICIES, **kw).items()}
    for i, pol in enumerate(DIFF_POLICIES):
        one = simulate(*args, pol=pol, **kw)
        for key, v in one.items():
            assert np.array_equal(np.asarray(v), sweep[key][i]), \
                (pol.name, key)


# ---------------------------------------------------------------------------
# phased envelope: the accuracy claim covers drifting traces too
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4)
def _phased_pair_48(policy_set: str):
    spec = TG.PHASED_SPECS["PHASED48"]
    tr = TG.generate(spec, seed=0)
    pols = DIFF_POLICIES if policy_set == "mechanisms" \
        else BL.LABELING_LADDER
    return _run_pair(tr, spec.n_warps, spec.lines_per_instr, pols)


def test_phased_tolerance_and_ordering_at_48_warps():
    """Same envelope as the steady-state rung 3 (|IPC| ≤ 2%, makespan ≤
    2.5%, identical policy ordering), on the drifting PHASED48 trace —
    measured worst |IPC| 0.9% / makespan 1.1% across the 4-policy
    mechanism set."""
    ev, wf = _phased_pair_48("mechanisms")
    ipc_rel = np.abs(wf["ipc"] - ev["ipc"]) / ev["ipc"]
    mk_rel = np.abs(wf["makespan"] - ev["makespan"]) / ev["makespan"]
    assert ipc_rel.max() <= 0.02, ipc_rel
    assert mk_rel.max() <= 0.025, mk_rel
    assert np.array_equal(np.argsort(wf["ipc"]), np.argsort(ev["ipc"])), \
        (wf["ipc"], ev["ipc"])


def test_phased_labeling_ladder_cross_engine_envelope():
    """The labeling modes (stale freeze, online windows, oracle
    substitution) must deviate identically in both engines: same ≤2% /
    ≤2.5% envelope across the 5-policy ladder. Ordering is NOT asserted
    here — stale and default-window online are a designed near-tie at 48
    warps (the gap opens at 256+; see benchmarks/phased_bench.py)."""
    ev, wf = _phased_pair_48("ladder")
    ipc_rel = np.abs(wf["ipc"] - ev["ipc"]) / ev["ipc"]
    mk_rel = np.abs(wf["makespan"] - ev["makespan"]) / ev["makespan"]
    assert ipc_rel.max() <= 0.02, ipc_rel
    assert mk_rel.max() <= 0.025, mk_rel
    # oracle labels bypass the classifier identically in both engines:
    # bypass totals must agree to the envelope too
    oi = [p.name for p in BL.LABELING_LADDER].index("MeDiC-oracle")
    np.testing.assert_allclose(wf["bypasses"][oi], ev["bypasses"][oi],
                               rtol=0.02)


def test_oracle_policy_without_oracle_types_rejected():
    """labeling='oracle' READS the ground-truth labels; omitting them
    must raise (a silent zeros fallback would label every warp all-miss)."""
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM)
    with pytest.raises(ValueError, match="oracle"):
        simulate(*args, pol=BL.MEDIC_ORACLE, **kw)
    with pytest.raises(ValueError, match="oracle"):
        simulate_sweep(*args, (BL.BASELINE, BL.MEDIC_ORACLE), **kw)
    # ...and passing the trace's labels makes the same calls legal
    simulate(*args, pol=BL.MEDIC_ORACLE,
             oracle_types=jnp.asarray(tr["oracle_wtype"]), **kw)


def test_unknown_engine_rejected():
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    with pytest.raises(ValueError, match="unknown engine"):
        simulate(jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                 jnp.asarray(tr["compute_gap"]), n_warps=spec.n_warps,
                 lanes=spec.lines_per_instr, prm=PRM, pol=BL.MEDIC,
                 engine="warp-drive")


# ---------------------------------------------------------------------------
# batched classifier.observe == N sequential scalar observes
# ---------------------------------------------------------------------------

def _observe_kw(interval=16):
    return dict(sampling_interval=interval, mostly_hit_threshold=0.8,
                mostly_miss_threshold=0.2)


def _states_equal(a: CLF.ClassifierState, b: CLF.ClassifierState):
    for name in a._fields:
        assert np.array_equal(np.asarray(getattr(a, name)),
                              np.asarray(getattr(b, name))), name


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batched_observe_equals_sequential_scalar(seed):
    """One batched observe over N DISTINCT warps == N scalar observes,
    in any order, including the weight-0 (invalid lane) path."""
    rng = np.random.default_rng(seed)
    n = 24
    batched = seq = CLF.init(n)
    for _ in range(40):                       # ~2.5 windows per warp
        warps = rng.permutation(n)[:rng.integers(1, n + 1)]
        hits = rng.random(warps.size) < 0.6
        weights = (rng.random(warps.size) < 0.8).astype(np.int32)
        batched = CLF.observe(batched, jnp.asarray(warps),
                              jnp.asarray(hits), weight=jnp.asarray(weights),
                              **_observe_kw())
        for w, h, wt in zip(warps, hits, weights):
            seq = CLF.observe(seq, jnp.asarray(w), jnp.asarray(h),
                              weight=jnp.asarray([int(wt)]), **_observe_kw())
        _states_equal(batched, seq)


@pytest.mark.parametrize("seed", [0, 1])
def test_gathered_observe_matches_full_observe(seed):
    """The wavefront's O(B) gather/scatter observe must equal the full
    classifier.observe for distinct warp ids (an untouched warp's window
    can never reset, so restricting the update to touched rows is
    lossless)."""
    from repro.core.engine.wavefront import _observe_gathered
    prm = SimParams(sampling_interval=8)
    rng = np.random.default_rng(seed)
    n = 32
    full = gath = CLF.init(n)
    for _ in range(60):
        warps = rng.permutation(n)[:rng.integers(1, 12)]
        hits = rng.random(warps.size) < 0.5
        weights = (rng.random(warps.size) < 0.9).astype(np.int32)
        full = CLF.observe(full, jnp.asarray(warps), jnp.asarray(hits),
                           sampling_interval=prm.sampling_interval,
                           mostly_hit_threshold=prm.mostly_hit_threshold,
                           mostly_miss_threshold=prm.mostly_miss_threshold,
                           weight=jnp.asarray(weights))
        gath = _observe_gathered(gath, jnp.asarray(warps),
                                 jnp.asarray(hits), jnp.asarray(weights),
                                 jnp.asarray(weights), prm, PA_DEFAULT)
        _states_equal(full, gath)


@pytest.mark.parametrize("policy", [BL.MEDIC_STALE,
                                    BL.with_labeling(BL.MEDIC, "online",
                                                     "MeDiC-w8",
                                                     reclass_interval=8)])
def test_gathered_observe_matches_full_observe_labeling_knobs(policy):
    """The policy-visible window/freeze knobs must behave identically in
    the wavefront's O(B) gathered observe and the full classifier.observe
    the event engine uses — stale's one-window label freeze included."""
    from repro.core.engine.wavefront import _observe_gathered
    from repro.policy import ops as POL
    pa = to_arrays(policy)
    prm = SimParams(sampling_interval=16)
    interval = POL.reclass_interval(pa, prm.sampling_interval)
    max_windows = POL.reclass_max_windows(pa)
    rng = np.random.default_rng(3)
    n = 16
    full = gath = CLF.init(n)
    for step in range(200):
        warps = rng.permutation(n)[:rng.integers(1, 10)]
        # drift the ground truth mid-run so stale vs online labels differ
        p_hit = 0.9 if step < 100 else 0.1
        hits = rng.random(warps.size) < p_hit
        weights = (rng.random(warps.size) < 0.9).astype(np.int32)
        full = CLF.observe(full, jnp.asarray(warps), jnp.asarray(hits),
                           sampling_interval=interval,
                           mostly_hit_threshold=prm.mostly_hit_threshold,
                           mostly_miss_threshold=prm.mostly_miss_threshold,
                           weight=jnp.asarray(weights),
                           max_windows=max_windows)
        gath = _observe_gathered(gath, jnp.asarray(warps),
                                 jnp.asarray(hits), jnp.asarray(weights),
                                 jnp.asarray(weights), prm, pa)
        _states_equal(full, gath)
    if policy.labeling == "stale":
        # the run drove warps through multiple windows, so the freeze
        # path (windows >= max_windows) was actually exercised
        assert np.asarray(gath.windows).max() >= 2


def test_batched_observe_window_resets_fire_identically():
    """Warps straddling the sampling boundary must reset (and re-classify)
    on exactly the same observe call in batched and scalar form."""
    interval = 8
    n = 4
    batched = seq = CLF.init(n)
    # drive warp w with hit-pattern w%2; after `interval` observes each
    # warp's window must have reset exactly once
    for step in range(interval):
        warps = jnp.arange(n)
        hits = jnp.asarray([w % 2 == 0 for w in range(n)])
        batched = CLF.observe(batched, warps, hits,
                              **_observe_kw(interval))
        for w in range(n):
            seq = CLF.observe(seq, jnp.asarray(w), hits[w],
                              **_observe_kw(interval))
        _states_equal(batched, seq)
    assert np.all(np.asarray(batched.accesses) == 0)      # window reset
    assert np.all(np.asarray(batched.ratio)
                  == np.asarray([1.0, 0.0, 1.0, 0.0]))    # re-sampled


# ---------------------------------------------------------------------------
# fused scan backend: bitwise-equal to the unfused engine (ISSUE 6)
# ---------------------------------------------------------------------------

def _run_backends(trace, n_warps, lanes, policies, backends,
                  bkw="scan_backend", **kw0):
    args = (jnp.asarray(trace["lines"]), jnp.asarray(trace["pcs"]),
            jnp.asarray(trace["compute_gap"]))
    kw = dict(n_warps=n_warps, lanes=lanes, prm=PRM, engine="wavefront",
              **kw0)
    if "oracle_wtype" in trace:
        kw["oracle_types"] = jnp.asarray(trace["oracle_wtype"])
    outs = {b: simulate_sweep(*args, policies, **{bkw: b}, **kw)
            for b in backends}
    return {b: {k: np.asarray(v) for k, v in o.items()}
            for b, o in outs.items()}


@pytest.mark.parametrize("workload", WL.WORKLOAD_NAMES)
def test_fused_backend_bitwise_on_workload_matrix(workload):
    """scan_backend="fused" (the auto default on CPU) must equal the
    pre-fusion "ref" path BIT-FOR-BIT on every metric across the full
    15-workload × 4-policy matrix: the fused timing pass only swaps in
    exactly-associative primitives, top_k selection is tie-identical to
    the stable argsort, and the hoisted cache-pass bookkeeping is
    integer accumulation."""
    spec = WL.WORKLOADS[workload]
    tr = WL.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         DIFF_POLICIES, ("ref", "fused"))
    for k in outs["ref"]:
        assert np.array_equal(outs["ref"][k], outs["fused"][k],
                              equal_nan=True), k


def test_fused_backend_bitwise_on_phased():
    """Same bitwise claim on a drifting-intensity PHASED trace — the
    non-dyadic compute_gap schedule is what would expose any rounding
    difference between the formulations."""
    spec = TG.PHASED_SPECS["PHASED48"]
    tr = TG.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         (BL.BASELINE, BL.MEDIC), ("ref", "fused"))
    for k in outs["ref"]:
        assert np.array_equal(outs["ref"][k], outs["fused"][k],
                              equal_nan=True), k


def test_fused_backend_bitwise_wave_of_one():
    """exact=True corner: a wave of one warp uses the plain busy-until
    floor; the fused gathered floor must stay bitwise there too."""
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         (BL.MEDIC,), ("ref", "fused"), wave_size=1)
    for k in outs["ref"]:
        assert np.array_equal(outs["ref"][k], outs["fused"][k],
                              equal_nan=True), k


def test_pallas_backend_close_at_engine_level():
    """scan_backend="pallas" (interpret-forced on CPU) through the whole
    engine: chunk re-association may round non-dyadic floats, so the
    claim is allclose, not bitwise. Kept tiny — interpret mode runs the
    kernel chunk loop in Python."""
    spec = dataclasses.replace(
        TG.TraceSpec.from_workload(WL.WORKLOADS["BFS"]),
        n_warps=12, n_instr=8)
    tr = TG.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         (BL.MEDIC,), ("ref", "pallas"))
    for k in outs["ref"]:
        np.testing.assert_allclose(outs["pallas"][k], outs["ref"][k],
                                   rtol=1e-5, atol=1e-5, err_msg=k)


def test_topk_selection_ties_match_stable_argsort():
    """The fused wave selection: `top_k(-ready)` must break equal-ready
    ties exactly like the stable ascending argsort (lower warp id wins)
    — fuzzed over heavily-tied readiness vectors."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        w = int(rng.integers(2, 200))
        b = int(rng.integers(1, w + 1))
        # few distinct values => many ties
        ready = rng.choice(rng.uniform(0, 10, 3), size=w)
        active = rng.random(w) < 0.8
        r = jnp.asarray(ready, jnp.float32)
        a = jnp.asarray(active)
        ref = np.argsort(np.where(active, ready, np.inf),
                         kind="stable")[:b]
        got = np.asarray(
            jax.lax.top_k(jnp.where(a, -r, -jnp.inf), b)[1])
        assert np.array_equal(ref, got), (w, b, ready, active)


def test_scan_backend_validation():
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM,
              pol=BL.MEDIC)
    with pytest.raises(ValueError, match="scan_backend"):
        simulate(*args, engine="wavefront", scan_backend="vector9", **kw)
    with pytest.raises(ValueError, match="only meaningful"):
        simulate(*args, engine="event", scan_backend="fused", **kw)


# ---------------------------------------------------------------------------
# fused cache backend: bitwise-equal to the per-lane ref pass (ISSUE 8)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", WL.WORKLOAD_NAMES)
def test_cache_fused_bitwise_on_workload_matrix(workload):
    """cache_backend="fused" (the auto default on CPU) must equal the
    per-lane "ref" cache pass BIT-FOR-BIT on every metric across the
    full 15-workload × 4-policy matrix: the one-sweep reformulation
    computes every slot's row from lane-start state (exactly what the
    ref scatters write) and resolves same-set conflicts last-write-wins
    in slot order."""
    spec = WL.WORKLOADS[workload]
    tr = WL.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         DIFF_POLICIES, ("ref", "fused"),
                         bkw="cache_backend")
    for k in outs["ref"]:
        assert np.array_equal(outs["ref"][k], outs["fused"][k],
                              equal_nan=True), k


@pytest.mark.parametrize("spec_name", ["PHASED48", "PHASED_RECOVER48"])
def test_cache_fused_bitwise_on_phased(spec_name):
    """Same bitwise claim on the drifting-intensity and recovery-shaped
    phased traces — window resets, relabeling, and EAF generation bumps
    all land mid-run there."""
    specs = {**TG.PHASED_SPECS, **TG.PHASED_RECOVER_SPECS}
    spec = specs[spec_name]
    tr = TG.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         (BL.BASELINE, BL.MEDIC), ("ref", "fused"),
                         bkw="cache_backend")
    for k in outs["ref"]:
        assert np.array_equal(outs["ref"][k], outs["fused"][k],
                              equal_nan=True), k


def test_cache_fused_bitwise_wave_of_one():
    """A wave of one warp still aliases sets ACROSS LANES of the same
    warp; the fused pass must stay bitwise in that degenerate shape."""
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         (BL.MEDIC,), ("ref", "fused"),
                         bkw="cache_backend", wave_size=1)
    for k in outs["ref"]:
        assert np.array_equal(outs["ref"][k], outs["fused"][k],
                              equal_nan=True), k


def test_cache_fused_bitwise_both_backends_fused():
    """Both passes fused at once (the shipping CPU default) must still
    equal the double-ref engine bitwise — the two fusions compose."""
    spec = WL.WORKLOADS["BFS"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM,
              engine="wavefront")
    ref = simulate_sweep(*args, DIFF_POLICIES, scan_backend="ref",
                         cache_backend="ref", **kw)
    fus = simulate_sweep(*args, DIFF_POLICIES, scan_backend="fused",
                         cache_backend="fused", **kw)
    for k in ref:
        assert np.array_equal(np.asarray(ref[k]), np.asarray(fus[k]),
                              equal_nan=True), k


def test_cache_pallas_backend_bitwise_at_engine_level():
    """cache_backend="pallas" (interpret-forced on CPU) through the
    whole engine. The cache pass is integer/select arithmetic — no
    re-associated float reductions — so unlike the timing-pass kernel
    this one is asserted BITWISE. Kept tiny: interpret mode runs the
    lane grid in Python."""
    spec = dataclasses.replace(
        TG.TraceSpec.from_workload(WL.WORKLOADS["BFS"]),
        n_warps=12, n_instr=8)
    tr = TG.generate(spec, seed=0)
    outs = _run_backends(tr, spec.n_warps, spec.lines_per_instr,
                         (BL.MEDIC,), ("ref", "pallas"),
                         bkw="cache_backend")
    for k in outs["ref"]:
        assert np.array_equal(outs["ref"][k], outs["pallas"][k],
                              equal_nan=True), k


def test_cache_backend_validation():
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM,
              pol=BL.MEDIC)
    with pytest.raises(ValueError, match="cache_backend"):
        simulate(*args, engine="wavefront", cache_backend="sweep9", **kw)
    with pytest.raises(ValueError, match="only meaningful"):
        simulate(*args, engine="event", cache_backend="fused", **kw)
