"""Unified policy engine tests: branchless ops, vmapped-sweep parity with
per-policy `simulate`, and array-pool parity with the dict reference."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as BL
from repro.core import warp_types as WT
from repro.core import workloads as WL
from repro.core.simulator import SimParams, simulate, simulate_sweep
from repro.policy import (BYPASS_MECHS, DecisionTables, Policy,
                          stack_policies, to_arrays)
from repro.serving.pool import MedicPoolManager, PoolConfig
from repro.serving.pool_ref import DictPoolManager

PRM = SimParams()

# one policy per mechanism family — exercises every branchless select lane
PARITY_POLICIES = (BL.BASELINE, BL.MEDIC, BL.PCAL, BL.EAF, BL.rand(0.4))


# ---------------------------------------------------------------------------
# PolicyArrays / spec
# ---------------------------------------------------------------------------

def test_to_arrays_one_hot():
    pa = to_arrays(BL.MEDIC)
    assert np.asarray(pa.bypass_sel).sum() == 1.0
    assert np.asarray(pa.bypass_sel)[BYPASS_MECHS.index("medic")] == 1.0
    assert float(pa.sched_medic) == 1.0
    base = to_arrays(BL.BASELINE)
    assert float(base.sched_medic) == 0.0
    assert np.asarray(base.ins_sel)[0] == 1.0   # lru


def test_stack_policies_shapes():
    pa = stack_policies(PARITY_POLICIES)
    assert pa.bypass_sel.shape == (len(PARITY_POLICIES), len(BYPASS_MECHS))
    assert pa.rand_p.shape == (len(PARITY_POLICIES),)


def test_policy_validates_mechanism_names():
    with pytest.raises(ValueError):
        Policy("bad", bypass="nope")
    with pytest.raises(ValueError):
        Policy("bad", insertion="nope")


# ---------------------------------------------------------------------------
# decision tables (host-side mirror of the ops)
# ---------------------------------------------------------------------------

def test_decision_tables_medic_match_warp_type_predicates():
    tb = DecisionTables.from_arrays(
        to_arrays(Policy("m", bypass="medic", insertion="medic",
                         scheduler="medic")), rrip_max=7)
    for t in range(WT.NUM_TYPES):
        assert tb.bypass_by_type[t] == bool(WT.is_bypass_type(jnp.int32(t)))
        assert tb.hp_by_type[t] == bool(WT.is_priority_type(jnp.int32(t)))
        assert tb.rank_by_type[t] == int(WT.insertion_rank(jnp.int32(t), 6))


def test_decision_tables_lru_neutral():
    tb = DecisionTables.from_arrays(to_arrays(Policy("lru")), rrip_max=7)
    assert not tb.bypass_by_type.any()
    assert not tb.hp_by_type.any()
    assert (tb.rank_by_type == 0).all()


# ---------------------------------------------------------------------------
# vmapped sweep == per-policy simulate, bit for bit
# ---------------------------------------------------------------------------

def test_simulate_sweep_matches_per_policy_bitwise():
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM)
    sweep = {k: np.asarray(v)
             for k, v in simulate_sweep(*args, PARITY_POLICIES, **kw).items()}
    for i, pol in enumerate(PARITY_POLICIES):
        one = simulate(*args, pol=pol, **kw)
        for key, v in one.items():
            assert np.array_equal(np.asarray(v), sweep[key][i]), \
                (pol.name, key)


def test_simulate_sweep_seed_stacked_axes():
    spec = WL.WORKLOADS["BP"]
    trs = [WL.generate(spec, seed=s) for s in (0, 1)]
    lines = jnp.stack([jnp.asarray(t["lines"]) for t in trs])
    pcs = jnp.stack([jnp.asarray(t["pcs"]) for t in trs])
    gap = jnp.stack([jnp.asarray(t["compute_gap"]) for t in trs])
    pols = (BL.BASELINE, BL.MEDIC)
    out = simulate_sweep(lines, pcs, gap, pols, n_warps=spec.n_warps,
                         lanes=spec.lines_per_instr, prm=PRM)
    assert out["ipc"].shape == (len(pols), 2)          # [P, S]
    # seed 0 column must equal the unstacked sweep on seed 0
    flat = simulate_sweep(jnp.asarray(trs[0]["lines"]),
                          jnp.asarray(trs[0]["pcs"]),
                          jnp.asarray(trs[0]["compute_gap"]), pols,
                          n_warps=spec.n_warps, lanes=spec.lines_per_instr,
                          prm=PRM)
    assert np.array_equal(np.asarray(out["ipc"][:, 0]),
                          np.asarray(flat["ipc"]))


def test_single_trace_shared_across_policies():
    """The policy is a traced argument: running N policies must not add
    N jit traces (that was the seed's retracing bug)."""
    from repro.core.simulator import _simulate_one
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM)
    before = _simulate_one._cache_size()
    for pol in PARITY_POLICIES:
        simulate(*args, pol=pol, **kw)
    after = _simulate_one._cache_size()
    assert after - before <= 1


# ---------------------------------------------------------------------------
# array pool == dict pool on a recorded access trace
# ---------------------------------------------------------------------------

def _replay(policy: str, seed: int = 0, steps: int = 300):
    cfg = PoolConfig(budget_blocks=24, sampling_interval=8, policy=policy,
                     fetch_occupancy=2.0)
    ev_a, ev_b = [], []
    arr = MedicPoolManager(cfg, max_seqs=8, on_evict=ev_a.append)
    ref = DictPoolManager(cfg, max_seqs=8, on_evict=ev_b.append)
    rng = np.random.default_rng(seed)
    for step in range(steps):
        op = rng.random()
        slot = int(rng.integers(0, 6))
        if op < 0.05:
            arr.reset_slot(slot)
            ref.reset_slot(slot)
        elif op < 0.15:
            key = (slot, int(rng.integers(0, 50)))
            stype = int(ref.seq_type[slot])
            arr.insert_prefill(key, stype)
            ref.insert_prefill(key, stype)
        else:
            hot = rng.random() < 0.5
            blocks = [int(rng.integers(0, 4 if hot else 1000))
                      for _ in range(int(rng.integers(1, 5)))]
            ra, fa = arr.access(slot, blocks, float(step))
            rb, fb = ref.access(slot, blocks, float(step))
            assert ra == rb and fa == fb, step
    return arr, ref, ev_a, ev_b


@pytest.mark.parametrize("policy", ["medic", "lru"])
def test_array_pool_matches_dict_pool(policy):
    arr, ref, ev_a, ev_b = _replay(policy)
    sa, sb = arr.snapshot(), ref.snapshot()
    assert set(sa) == set(sb)
    for k in sa:
        assert np.array_equal(np.asarray(sa[k]), np.asarray(sb[k]),
                              equal_nan=True), k
    # full residency contents + eviction callbacks, in order
    assert arr.resident == ref.resident
    assert ev_a == ev_b
    assert len(ev_a) > 0                      # the trace exercised eviction


def test_array_pool_insert_at_budget_is_vectorized_aging():
    """Filling past budget ages residents via one clamp, same as the dict's
    per-key loop: after pressure, earlier cold inserts carry higher rank."""
    cfg = PoolConfig(budget_blocks=4, sampling_interval=4, policy="lru")
    pool = MedicPoolManager(cfg, max_seqs=2)
    for blk in range(4):
        pool.access(0, [blk], 0.0)
    ranks = pool.resident
    assert len(ranks) == 4
    assert max(ranks.values()) > 0            # aging actually happened
    pool.access(0, [99], 1.0)                 # forces an eviction
    assert len(pool.resident) == 4
    assert pool.snapshot()["evictions_by_type"].sum() == 1


def test_paper_figures_run_covers_off_sweep_policies():
    """_run serves sweep members from the batched cache and anything else
    (e.g. BL.RAND_SWEEP points) through an equivalent one-off path."""
    from benchmarks import paper_figures as PF
    on = PF._run("BP", BL.BASELINE)
    off = PF._run("BP", BL.rand(0.1))           # not in SWEEP_POLICIES
    assert "sweep_wall_s" in on and "sweep_wall_s" in off
    assert float(off["ipc"]) > 0
    # a same-named but differently-configured policy must not be served
    # from the sweep cache
    tweaked = dataclasses.replace(BL.BASELINE, insertion="eaf")
    out = PF._run("BP", tweaked)
    assert float(out["l2_hits"]) != float(on["l2_hits"]) or \
        float(out["ipc"]) != float(on["ipc"])


def test_classify_np_matches_jnp():
    rng = np.random.default_rng(0)
    for _ in range(200):
        acc = int(rng.integers(1, 64))
        hits = int(rng.integers(0, acc + 1))
        r = hits / acc
        a = WT.classify_np(r, acc, min_samples=1)
        b = int(WT.classify(jnp.float32(r), jnp.int32(acc), min_samples=1))
        assert a == b, (hits, acc)
