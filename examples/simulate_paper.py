"""Reproduce the paper's headline table (Fig 7) over all 15 workloads.

    PYTHONPATH=src python examples/simulate_paper.py [--quick] [--seeds N]

``--seeds N`` averages each speedup over N trace seeds; the seeds ride
the policy sweep in one jitted call per workload (the vectorized
tracegen path stacks them via ``generate_batch``).
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    def positive_int(v):
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("need at least 1 seed")
        return n

    ap.add_argument("--seeds", type=positive_int, default=1, metavar="N",
                    help="trace seeds per workload (default 1)")
    args = ap.parse_args()

    from benchmarks.paper_figures import fig7_performance
    from repro.core.workloads import WORKLOAD_NAMES

    wls = ("BFS", "SSSP", "BP", "CONS") if args.quick else WORKLOAD_NAMES
    rows, derived = fig7_performance(wls, seeds=tuple(range(args.seeds)))

    policies = []
    for r in rows:
        if r["policy"] not in policies:
            policies.append(r["policy"])
    print(f"{'workload':10s}" + "".join(f"{p:>12s}" for p in policies))
    for wl in wls:
        vals = {r["policy"]: r["speedup"] for r in rows
                if r["workload"] == wl}
        print(f"{wl:10s}" + "".join(f"{vals[p]:>12.3f}" for p in policies))
    print("\nharmonic-mean speedups (paper: WByp 1.336, MeDiC 1.415, "
          "MeDiC vs best prior 1.218):")
    for k, v in derived.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
