"""Reproduce the paper's headline table (Fig 7) over all 15 workloads.

    PYTHONPATH=src python examples/simulate_paper.py [--quick] [--seeds N]
                                                     [--engine ENGINE]
                                                     [--stress]

``--seeds N`` averages each speedup over N trace seeds; the seeds ride
the policy sweep in one jitted call per workload (the vectorized
tracegen path stacks them via ``generate_batch``).

``--engine wavefront`` runs the Fig 7 sweep on the batched wavefront
engine (same orderings within the documented tolerance, DESIGN.md §9).

``--stress`` runs the STRESS_SPECS scheduler-stress matrix (1k–4k warps)
on the wavefront engine — the only path that can — and prints the
per-scenario policy rankings.

Everything routes through the declarative ``repro.api`` layer
(DESIGN.md §10): the fig7 table via ``benchmarks.paper_figures`` (one
single-scenario ``Experiment`` per workload, golden-pinned), the stress
matrix via ``benchmarks.engine_bench.run_stress_matrix`` (one
``Experiment`` whose plan compiles to one jitted call per trace shape).
"""
import argparse
import os
import sys

# make `benchmarks` importable when run as a script from anywhere
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def run_stress():
    import numpy as np

    from benchmarks.engine_bench import STRESS_POLICIES, run_stress_matrix
    from repro.core import tracegen as TG

    print("stress matrix (wavefront engine, "
          f"policies: {', '.join(p.name for p in STRESS_POLICIES)})")
    results, walls, group_walls = run_stress_matrix()
    names = [p.name for p in STRESS_POLICIES]
    for name, spec in TG.STRESS_SPECS.items():
        ipc = np.asarray(results[name]["ipc"], dtype=float)
        order = np.argsort(-ipc)
        ranking = " > ".join(f"{names[i]}({ipc[i]:.3f})" for i in order)
        print(f"  {name:10s} [{spec.n_warps:4d} warps, "
              f"group wall {walls[name]:6.1f}s]  {ranking}")
    print(f"total wall: {sum(group_walls):.1f}s "
          f"({len(group_walls)} jitted sweep calls, one per trace-shape "
          "bucket of the compiled plan)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")

    def positive_int(v):
        n = int(v)
        if n < 1:
            raise argparse.ArgumentTypeError("need at least 1 seed")
        return n

    ap.add_argument("--seeds", type=positive_int, default=1, metavar="N",
                    help="trace seeds per workload (default 1)")
    ap.add_argument("--engine", choices=("event", "wavefront"),
                    default="event",
                    help="simulation engine (default: exact event loop)")
    ap.add_argument("--stress", action="store_true",
                    help="run the 1k-4k-warp stress matrix instead of "
                         "the paper table (implies the wavefront engine)")
    args = ap.parse_args()

    if args.stress:
        run_stress()
        return

    from benchmarks.paper_figures import fig7_performance
    from repro.core.workloads import WORKLOAD_NAMES

    wls = ("BFS", "SSSP", "BP", "CONS") if args.quick else WORKLOAD_NAMES
    rows, derived = fig7_performance(wls, seeds=tuple(range(args.seeds)),
                                     engine=args.engine)

    policies = []
    for r in rows:
        if r["policy"] not in policies:
            policies.append(r["policy"])
    print(f"engine: {args.engine}")
    print(f"{'workload':10s}" + "".join(f"{p:>12s}" for p in policies))
    for wl in wls:
        vals = {r["policy"]: r["speedup"] for r in rows
                if r["workload"] == wl}
        print(f"{wl:10s}" + "".join(f"{vals[p]:>12.3f}" for p in policies))
    print("\nharmonic-mean speedups (paper: WByp 1.336, MeDiC 1.415, "
          "MeDiC vs best prior 1.218):")
    for k, v in derived.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
