"""Quickstart: the MeDiC policy core in 60 seconds.

Runs one memory-intensive workload through the altitude-A simulator under
the baseline and full-MeDiC policies via the declarative experiment API —
a `Scenario` names what to simulate, an `Experiment` crosses it with
policies, and the plan compiler lowers the whole thing to a single
vmapped, jitted `simulate_sweep` call — then prints the headline effects
the paper predicts straight off the labeled `ResultSet`: bypass volume,
queue-delay relief, warp-type conversion, and speedup.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import api
from repro.core import baselines as BL
from repro.core import warp_types as WT


def main():
    exp = api.Experiment("quickstart",
                         scenarios=(api.Scenario.workload("BFS"),),
                         policies=(BL.BASELINE, BL.MEDIC))
    print(exp.compile().describe())
    rs = exp.run()

    spec = exp.scenarios[0].trace_spec
    print(f"\nworkload: {spec.name} ({spec.n_warps} warps, "
          f"{spec.n_instr} memory instructions each)")

    # the per-policy table, by label — no positional v[0]/v[1] slicing
    for row in rs.to_rows(metrics=("ipc", "miss_rate", "mean_qdelay",
                                   "bypasses")):
        types = np.bincount(
            np.asarray(rs.get(policy=row["policy"])["warp_type"]),
            minlength=WT.NUM_TYPES)
        print(f"\n[{row['policy']}]")
        print(f"  IPC proxy          : {row['ipc']:.4f}")
        print(f"  L2 miss rate       : {row['miss_rate']:.3f}")
        print(f"  mean L2 queue delay: {row['mean_qdelay']:.1f} cyc")
        print(f"  bypassed requests  : {int(row['bypasses'])}")
        print("  warp types         : " + ", ".join(
            f"{n}={c}" for n, c in zip(WT.TYPE_NAMES, types)))

    speedup = rs.speedup_over("Baseline")["BFS"]["MeDiC"]
    print(f"\nMeDiC speedup: {speedup:.3f}x")


if __name__ == "__main__":
    main()
