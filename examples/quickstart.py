"""Quickstart: the MeDiC policy core in 60 seconds.

Runs one memory-intensive workload through the altitude-A simulator under
the baseline and full-MeDiC policies — both in a single vmapped
`simulate_sweep` call (the branchless policy engine compiles once for any
set of policies) — and prints the headline effects the paper predicts:
bypass volume, queue-delay relief, warp-type conversion, and speedup.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as BL
from repro.core import warp_types as WT
from repro.core import workloads as WL
from repro.core.simulator import SimParams, simulate_sweep


def main():
    spec = WL.WORKLOADS["BFS"]
    trace = WL.generate(spec, seed=0)
    args = (jnp.asarray(trace["lines"]), jnp.asarray(trace["pcs"]),
            jnp.asarray(trace["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr,
              prm=SimParams())

    sweep = simulate_sweep(*args, [BL.BASELINE, BL.MEDIC], **kw)
    base = {k: v[0] for k, v in sweep.items()}
    medic = {k: v[1] for k, v in sweep.items()}

    print(f"workload: {spec.name} ({spec.n_warps} warps, "
          f"{spec.n_instr} memory instructions each)")
    for name, out in (("baseline", base), ("MeDiC", medic)):
        types = np.bincount(np.asarray(out["warp_type"]),
                            minlength=WT.NUM_TYPES)
        print(f"\n[{name}]")
        print(f"  IPC proxy          : {float(out['ipc']):.4f}")
        print(f"  L2 miss rate       : {float(out['miss_rate']):.3f}")
        print(f"  mean L2 queue delay: {float(out['mean_qdelay']):.1f} cyc")
        print(f"  bypassed requests  : {int(out['bypasses'])}")
        print("  warp types         : " + ", ".join(
            f"{n}={c}" for n, c in zip(WT.TYPE_NAMES, types)))
    print(f"\nMeDiC speedup: {float(medic['ipc'])/float(base['ipc']):.3f}x")


if __name__ == "__main__":
    main()
