"""End-to-end training driver: a ~100M-parameter qwen3-family model for a
few hundred steps with the full production substrate — synthetic data
pipeline, AdamW + cosine schedule, gradient accumulation, checkpointing,
fault injection + restart, straggler detection.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--tiny]
"""
import argparse
import dataclasses

import jax

from repro.checkpoint.checkpointing import CheckpointManager
from repro.configs.base import OptimizerConfig, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import build_model
from repro.optim.optimizer import init_opt_state, make_train_step
from repro.runtime.fault_tolerance import FailureInjector, run_fault_tolerant


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true",
                    help="tiny config for CI-speed runs")
    ap.add_argument("--ckpt", default="/tmp/repro_train100m")
    args = ap.parse_args()

    if args.tiny:
        cfg = get_config("qwen3_1_7b").reduced(num_layers=2)
        seq, batch = 64, 8
    else:
        # ~100M params: 12 x 512 qwen3-family (qk-norm, GQA, tied embed)
        cfg = dataclasses.replace(
            get_config("qwen3_1_7b"), num_layers=12, d_model=512,
            num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32000, remat=False)
        seq, batch = 256, 8
    model = build_model(cfg)
    print(f"model: {cfg.name}-derived, {cfg.num_params/1e6:.1f}M params")

    params = model.init_params(jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    opt = init_opt_state(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg, microbatches=2))
    ds = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                global_batch=batch, n_chains=2))

    ck = CheckpointManager(args.ckpt, keep=2)
    res = run_fault_tolerant(
        step, params, opt, ds.iterator(), ckpt=ck,
        total_steps=args.steps, checkpoint_every=50,
        injector=FailureInjector(fail_at=(args.steps // 3,)),
        on_metrics=lambda s, m: print(
            f"step {s:4d} loss {m['loss']:.4f} lr {m['lr']:.2e} "
            f"gnorm {m['grad_norm']:.2f}") if s % 20 == 0 else None)

    losses = [m["loss"] for m in res.metrics_history]
    print(f"\nrestarts={res.restarts} straggler_events="
          f"{len(res.straggler_events)}")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
