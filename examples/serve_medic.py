"""Serve a small model with batched requests under the MeDiC pool manager
and print the policy A/B against LRU (altitude-B deployment of the paper).

    PYTHONPATH=src python examples/serve_medic.py
"""
from repro.configs.base import get_config
from repro.serving.engine import EngineConfig, run_ab
from repro.serving.pool import PoolConfig
from repro.serving.request import ServeWorkload


def main():
    cfg = get_config("qwen3_1_7b").reduced(num_layers=2)
    wl = ServeWorkload(n_requests=24, chat_frac=0.6)
    pool = PoolConfig(budget_blocks=48, block_tokens=16)
    out = run_ab(cfg, wl, pool, EngineConfig(max_slots=4, max_len=448))

    print(f"{'':22s}{'LRU':>12s}{'MeDiC':>12s}")
    for key in ("throughput", "completed", "mean_ttft", "mean_qdelay",
                "bypassed_blocks", "stall_steps"):
        a, b = out["lru"][key], out["medic"][key]
        print(f"{key:22s}{a:>12.3f}{b:>12.3f}" if isinstance(a, float)
              else f"{key:22s}{a:>12d}{b:>12d}")
    gain = out["medic"]["throughput"] / max(out["lru"]["throughput"], 1e-9)
    print(f"\nMeDiC throughput gain under pool oversubscription: {gain:.2f}x")

    # per-sequence-type view (the paper's Fig 2 analogue at the pool)
    import numpy as np
    print("\nper-sequence pool hit ratios (MeDiC run):")
    # re-run one engine to snapshot
    from repro.serving.engine import ServeEngine
    from repro.serving.request import generate_requests
    eng = ServeEngine(cfg, EngineConfig(max_slots=4, max_len=448), pool)
    eng.run(generate_requests(wl, seed=0), max_steps=800)
    snap = eng.pool.snapshot()
    ratios = snap["seq_hit_ratio"]
    print("  " + " ".join(f"{r:.2f}" for r in ratios if np.isfinite(r)))


if __name__ == "__main__":
    main()
