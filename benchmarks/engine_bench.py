"""Wavefront-vs-event engine benchmark (ISSUE 3 acceptance numbers).

Measures:

  * paper scale (48 warps): warm wall-clock of the 4-policy sweep on both
    engines (``speedup_48`` — report-only: on narrow CPUs without vector
    units both engines are element-work-bound and the ratio is small;
    fidelity at this scale is what the differential suite pins);
  * stress scale (HAMMER2K, 2048 warps): event vs wavefront on a single
    policy (``speedup_hammer2k`` — the CI floor: the event loop's
    per-request work grows O(W) with the warp population, the wavefront
    amortizes it over a wave);
  * the full ``STRESS_SPECS`` matrix × STRESS_POLICIES on the wavefront
    engine, scenarios grouped by trace shape so each group is ONE jitted
    ``simulate_sweep`` call (``stress_total_s``, ``hammer2k_s`` — the
    CI wall-clock budget). No other engine can run these at all.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.api.registry import STRESS_POLICIES
from repro.core import baselines as BL
from repro.core import tracegen as TG
from repro.core.simulator import Policy, SimParams, simulate_sweep
from repro.kernels.wavefront_scan import ops as WSCAN

PRM = SimParams()

#: what engine="wavefront" actually ran in this process — recorded in
#: every wavefront row so BENCH_*.json trajectories stay comparable
#: across PRs that change the default (event rows carry "-")
WF_BACKEND = WSCAN.resolve_backend("auto")


def _backend_of(engine: str) -> str:
    return WF_BACKEND if engine == "wavefront" else "-"


def block_tree(tree):
    """Wait for every array in a jax pytree (shared benchmark helper)."""
    jax.tree.map(lambda x: x.block_until_ready(), tree)


def _sweep_args(tr, idx=None):
    if idx is None:
        return (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                jnp.asarray(tr["compute_gap"]))
    return (jnp.asarray(tr["lines"][idx]), jnp.asarray(tr["pcs"][idx]),
            jnp.asarray(tr["compute_gap"][idx]))


def run_stress_matrix(policies: Sequence[Policy] = STRESS_POLICIES,
                      specs: Dict[str, TG.TraceSpec] = None,
                      seed: int = 0, prm: SimParams = PRM
                      ) -> Tuple[Dict[str, dict], Dict[str, float],
                                 List[float]]:
    """Run the stress scenario matrix on the wavefront engine, via the
    declarative ``repro.api`` layer.

    The plan compiler buckets scenarios by trace shape (I, W, L); each
    bucket rides the flat stacking axis of ONE jitted
    ``simulate_sweep(engine="wavefront")`` call, so the whole matrix is
    one call per distinct shape. Returns (per-scenario metrics with a
    leading policy axis, per-scenario wall seconds — the wall of the
    scenario's whole BUCKET call, compile included, so same-shape
    scenarios share one number — and the list of per-bucket walls whose
    sum is the matrix total).
    """
    specs = dict(specs or TG.STRESS_SPECS)
    exp = api.Experiment(
        "stress_matrix",
        tuple(api.Scenario.from_spec(s, seeds=(seed,), name=n)
              for n, s in specs.items()),
        tuple(policies), engine="wavefront", prm=prm)
    rs = exp.run()
    results = {name: rs.get(scenario=name, seed=seed) for name in specs}
    walls = {name: rs.wall_of(name) for name in specs}
    return results, walls, list(rs.call_walls())


def _timed_sweep(args, policies, **kw) -> float:
    """Warm wall-clock of one sweep: compile + first run, then time the
    second (warm runs are the meaningful timing on jitted paths)."""
    block_tree(simulate_sweep(*args, policies, **kw))
    t0 = time.perf_counter()
    block_tree(simulate_sweep(*args, policies, **kw))
    return time.perf_counter() - t0


def engine_scale(quick: bool = False) -> Tuple[List[dict], Dict]:
    """Engine A/B timings. Traces come from ``api.Scenario`` and the
    matrix goes through ``api.Experiment``; only the warm per-engine
    timing pairs call the ``simulate_sweep`` facade directly — they time
    the engine itself, and the api layer's own dispatch overhead is
    measured separately (benchmarks/api_bench.py)."""
    rows: List[dict] = []
    derived: Dict[str, object] = {}

    # ---- paper scale: 48 warps, 4 policies, warm ---------------------------
    scen = api.Scenario.workload("BFS")
    tr = scen.materialize()
    args = _sweep_args(tr, idx=0)
    (_, n_warps, lanes) = scen.shape
    kw = dict(n_warps=n_warps, lanes=lanes, prm=PRM)
    t_ev = _timed_sweep(args, STRESS_POLICIES,
                        engine="event", **kw)
    t_wf = _timed_sweep(args, STRESS_POLICIES,
                        engine="wavefront", **kw)
    rows.append({"scale": "48-warp sweep", "engine": "event",
                 "scan_backend": _backend_of("event"),
                 "policies": len(STRESS_POLICIES),
                 "wall_s": round(t_ev, 3)})
    rows.append({"scale": "48-warp sweep", "engine": "wavefront",
                 "scan_backend": _backend_of("wavefront"),
                 "policies": len(STRESS_POLICIES),
                 "wall_s": round(t_wf, 3)})
    derived["speedup_48"] = round(t_ev / t_wf, 2)

    # the stress-scale measurements are the expensive half; --quick is a
    # fast pass, so it stops at the 48-warp pair
    if quick:
        return rows, derived

    # ---- stress scale: HAMMER2K, one policy, both engines, WARM ------------
    # the event loop's per-request cost grows O(W) (classifier updates,
    # warp selection), so this is where the wavefront's amortization pays.
    # Measured warm floors on the narrow SSE2-only reference container:
    # 4.9x at HAMMER2K, 7.4x at HAMMER4K (DESIGN.md §9); vectorized CPUs
    # amortize the wavefront's wide ops further.
    sscen = api.Scenario.stress("HAMMER2K")
    st = sscen.materialize()
    sargs = _sweep_args(st, idx=0)
    (_, s_warps, s_lanes) = sscen.shape
    skw = dict(n_warps=s_warps, lanes=s_lanes, prm=PRM)
    ev2k = _timed_sweep(sargs, (BL.MEDIC,),
                        engine="event", **skw)
    wf2k = _timed_sweep(sargs, (BL.MEDIC,),
                        engine="wavefront", **skw)
    rows.append({"scale": "HAMMER2K 1-policy warm", "engine": "event",
                 "scan_backend": _backend_of("event"),
                 "policies": 1, "wall_s": round(ev2k, 2)})
    rows.append({"scale": "HAMMER2K 1-policy warm",
                 "engine": "wavefront",
                 "scan_backend": _backend_of("wavefront"), "policies": 1,
                 "wall_s": round(wf2k, 2)})
    derived["speedup_hammer2k"] = round(ev2k / wf2k, 1)

    # ---- HAMMER2K × 4 policies alone: the ISSUE's <60s budget point --------
    t0 = time.perf_counter()
    block_tree(simulate_sweep(*sargs, STRESS_POLICIES, engine="wavefront",
                              **skw))
    h2k4 = time.perf_counter() - t0
    rows.append({"scale": "HAMMER2K 4-policy cold", "engine": "wavefront",
                 "scan_backend": _backend_of("wavefront"),
                 "policies": len(STRESS_POLICIES),
                 "wall_s": round(h2k4, 2)})
    derived["hammer2k_s"] = round(h2k4, 2)

    # ---- the full stress matrix × 4 policies, wavefront only ---------------
    results, walls, group_walls = run_stress_matrix()
    for name in TG.STRESS_SPECS:
        rows.append({
            "scale": f"stress:{name} (shape-group wall)",
            "engine": "wavefront",
            "scan_backend": _backend_of("wavefront"),
            "policies": len(STRESS_POLICIES),
            "wall_s": round(walls[name], 2),
            "best_policy": STRESS_POLICIES[
                int(np.argmax(results[name]["ipc"]))].name,
        })
    derived["stress_total_s"] = round(sum(group_walls), 2)
    derived["stress_max_warps"] = max(
        s.n_warps for s in TG.STRESS_SPECS.values())
    derived["stress_scenarios"] = len(TG.STRESS_SPECS)
    return rows, derived


def fused_ab(quick: bool = False) -> Tuple[List[dict], Dict]:
    """In-run unfused-vs-fused A/B on the wavefront engine (ISSUE 6
    acceptance): both sides run warm in the SAME process on the same
    trace, so the ratio is meaningful even on noisy shared containers
    (never compare cross-run wall-clock — CHANGES.md PR 4 note).

    ``scan_backend="ref"`` is the pre-fusion multi-pass timing pass with
    argsort wave selection; ``"fused"`` the associative-scan + top_k
    path that ``"auto"`` resolves to on CPU. Outputs are bitwise-equal
    (tests/test_engine_differential.py), so this measures pure engine
    speed. The headline number is ``fused_speedup_wide1k`` — the 1024-
    warp point where the [Q, N] mask materialization and the O(W log W)
    argsort dominate; --quick stops at the cheap 48-warp pair and gates
    on ``fused_speedup_min`` only.

    The second half is the same A/B for the CACHE pass (ISSUE 8):
    ``cache_backend="ref"`` vs ``"fused"`` with the timing pass pinned
    to the default on both sides, best-of-3 warm walls, emitting
    ``cache_fused_speedup_bfs48`` (the CI floor) and, on the full run,
    ``cache_fused_speedup_wide1k``.
    """
    rows: List[dict] = []
    derived: Dict[str, object] = {}
    points = [("BFS48", api.Scenario.workload("BFS"), STRESS_POLICIES)]
    if not quick:
        points.append(("WIDE1K", api.Scenario.stress("WIDE1K"),
                       (BL.MEDIC,)))
    speedups = []
    for name, scen, policies in points:
        tr = scen.materialize()
        args = _sweep_args(tr, idx=0)
        (_, n_warps, lanes) = scen.shape
        kw = dict(n_warps=n_warps, lanes=lanes, prm=PRM,
                  engine="wavefront")
        t_ref = _timed_sweep(args, policies, scan_backend="ref", **kw)
        t_fused = _timed_sweep(args, policies, scan_backend="fused", **kw)
        for backend, wall in (("ref", t_ref), ("fused", t_fused)):
            rows.append({"scale": f"fused_ab:{name}",
                         "engine": "wavefront", "scan_backend": backend,
                         "policies": len(policies),
                         "wall_s": round(wall, 3)})
        sp = t_ref / t_fused
        speedups.append(sp)
        derived[f"fused_speedup_{name.lower()}"] = round(sp, 2)
    derived["fused_speedup_min"] = round(min(speedups), 2)

    # ---- cache-pass A/B (ISSUE 8): cache_backend ref vs fused --------------
    # same in-run convention, best-of-3 warm repetitions per side (the
    # cache pass is a smaller slice of the engine step than the timing
    # pass was, so single-shot warm walls are noisier than the ratio).
    # The timing pass rides the default backend on BOTH sides — this
    # isolates the cache-pass fusion.
    for name, scen, policies in points:
        tr = scen.materialize()
        args = _sweep_args(tr, idx=0)
        (_, n_warps, lanes) = scen.shape
        kw = dict(n_warps=n_warps, lanes=lanes, prm=PRM,
                  engine="wavefront")
        pols = (BL.MEDIC,)
        walls = {}
        for backend in ("ref", "fused"):
            best = min(_timed_sweep(args, pols, cache_backend=backend,
                                    **kw)
                       for _ in range(3))
            walls[backend] = best
            rows.append({"scale": f"cache_ab:{name}",
                         "engine": "wavefront", "cache_backend": backend,
                         "policies": len(pols),
                         "wall_s": round(best, 3)})
        derived[f"cache_fused_speedup_{name.lower()}"] = round(
            walls["ref"] / walls["fused"], 2)
    return rows, derived
