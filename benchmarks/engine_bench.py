"""Wavefront-vs-event engine benchmark (ISSUE 3 acceptance numbers).

Measures:

  * paper scale (48 warps): warm wall-clock of the 4-policy sweep on both
    engines (``speedup_48`` — report-only: on narrow CPUs without vector
    units both engines are element-work-bound and the ratio is small;
    fidelity at this scale is what the differential suite pins);
  * stress scale (HAMMER2K, 2048 warps): event vs wavefront on a single
    policy (``speedup_hammer2k`` — the CI floor: the event loop's
    per-request work grows O(W) with the warp population, the wavefront
    amortizes it over a wave);
  * the full ``STRESS_SPECS`` matrix × STRESS_POLICIES on the wavefront
    engine, scenarios grouped by trace shape so each group is ONE jitted
    ``simulate_sweep`` call (``stress_total_s``, ``hammer2k_s`` — the
    CI wall-clock budget). No other engine can run these at all.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as BL
from repro.core import tracegen as TG
from repro.core import workloads as WL
from repro.core.simulator import Policy, SimParams, simulate_sweep

PRM = SimParams()

# one policy per mechanism family — the stress-matrix comparison set
STRESS_POLICIES: Tuple[Policy, ...] = (BL.BASELINE, BL.PCAL, BL.WBYP,
                                       BL.MEDIC)


def _block(tree):
    jax.tree.map(lambda x: x.block_until_ready(), tree)


def _sweep_args(tr, idx=None):
    if idx is None:
        return (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
                jnp.asarray(tr["compute_gap"]))
    return (jnp.asarray(tr["lines"][idx]), jnp.asarray(tr["pcs"][idx]),
            jnp.asarray(tr["compute_gap"][idx]))


def run_stress_matrix(policies: Sequence[Policy] = STRESS_POLICIES,
                      specs: Dict[str, TG.TraceSpec] = None,
                      seed: int = 0, prm: SimParams = PRM
                      ) -> Tuple[Dict[str, dict], Dict[str, float],
                                 List[float]]:
    """Run the stress scenario matrix on the wavefront engine.

    Scenarios are grouped by trace shape (I, W, L); each group rides the
    seed axis of ONE jitted ``simulate_sweep(engine="wavefront")`` call,
    so the whole matrix is one call per distinct shape. Returns
    (per-scenario metrics with a leading policy axis, per-scenario wall
    seconds — the wall of the scenario's whole GROUP call, compile
    included, so same-shape scenarios share one number — and the list
    of per-group walls whose sum is the matrix total).
    """
    specs = dict(specs or TG.STRESS_SPECS)
    groups: Dict[tuple, List[str]] = {}
    for name, spec in specs.items():
        groups.setdefault(
            (spec.n_instr, spec.n_warps, spec.lines_per_instr), []
        ).append(name)

    results: Dict[str, dict] = {}
    walls: Dict[str, float] = {}
    group_walls: List[float] = []
    for (n_instr, n_warps, lanes), names in groups.items():
        batch = TG.generate_batch([specs[n] for n in names], seeds=(seed,))
        # [spec, seed=1, ...] -> ride the seed axis with the spec batch
        lines = jnp.asarray(batch["lines"][:, 0])
        pcs = jnp.asarray(batch["pcs"][:, 0])
        gap = jnp.asarray(batch["compute_gap"][:, 0])
        t0 = time.perf_counter()
        out = simulate_sweep(lines, pcs, gap, policies, n_warps=n_warps,
                             lanes=lanes, prm=prm, engine="wavefront")
        _block(out)
        wall = time.perf_counter() - t0
        out = {k: np.asarray(v) for k, v in out.items()}   # [P, spec, ...]
        group_walls.append(wall)
        for si, name in enumerate(names):
            results[name] = {k: v[:, si] for k, v in out.items()}
            walls[name] = wall
    return results, walls, group_walls


def _timed_sweep(args, policies, **kw) -> float:
    """Warm wall-clock of one sweep: compile + first run, then time the
    second (warm runs are the meaningful timing on jitted paths)."""
    _block(simulate_sweep(*args, policies, **kw))
    t0 = time.perf_counter()
    _block(simulate_sweep(*args, policies, **kw))
    return time.perf_counter() - t0


def engine_scale(quick: bool = False) -> Tuple[List[dict], Dict]:
    rows: List[dict] = []
    derived: Dict[str, object] = {}

    # ---- paper scale: 48 warps, 4 policies, warm ---------------------------
    spec = WL.WORKLOADS["BFS"]
    tr = WL.generate(spec, seed=0)
    args = _sweep_args(tr)
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr, prm=PRM)
    t_ev = _timed_sweep(args, STRESS_POLICIES,
                        engine="event", **kw)
    t_wf = _timed_sweep(args, STRESS_POLICIES,
                        engine="wavefront", **kw)
    rows.append({"scale": "48-warp sweep", "engine": "event",
                 "policies": len(STRESS_POLICIES),
                 "wall_s": round(t_ev, 3)})
    rows.append({"scale": "48-warp sweep", "engine": "wavefront",
                 "policies": len(STRESS_POLICIES),
                 "wall_s": round(t_wf, 3)})
    derived["speedup_48"] = round(t_ev / t_wf, 2)

    # the stress-scale measurements are the expensive half; --quick is a
    # fast pass, so it stops at the 48-warp pair
    if quick:
        return rows, derived

    # ---- stress scale: HAMMER2K, one policy, both engines, WARM ------------
    # the event loop's per-request cost grows O(W) (classifier updates,
    # warp selection), so this is where the wavefront's amortization pays.
    # Measured warm floors on the narrow SSE2-only reference container:
    # 4.9x at HAMMER2K, 7.4x at HAMMER4K (DESIGN.md §9); vectorized CPUs
    # amortize the wavefront's wide ops further.
    sspec = TG.STRESS_SPECS["HAMMER2K"]
    st = TG.generate(sspec, 0)
    sargs = _sweep_args(st)
    skw = dict(n_warps=sspec.n_warps, lanes=sspec.lines_per_instr,
               prm=PRM)
    ev2k = _timed_sweep(sargs, (BL.MEDIC,),
                        engine="event", **skw)
    wf2k = _timed_sweep(sargs, (BL.MEDIC,),
                        engine="wavefront", **skw)
    rows.append({"scale": "HAMMER2K 1-policy warm", "engine": "event",
                 "policies": 1, "wall_s": round(ev2k, 2)})
    rows.append({"scale": "HAMMER2K 1-policy warm",
                 "engine": "wavefront", "policies": 1,
                 "wall_s": round(wf2k, 2)})
    derived["speedup_hammer2k"] = round(ev2k / wf2k, 1)

    # ---- HAMMER2K × 4 policies alone: the ISSUE's <60s budget point --------
    t0 = time.perf_counter()
    _block(simulate_sweep(*sargs, STRESS_POLICIES, engine="wavefront",
                          **skw))
    h2k4 = time.perf_counter() - t0
    rows.append({"scale": "HAMMER2K 4-policy cold", "engine": "wavefront",
                 "policies": len(STRESS_POLICIES),
                 "wall_s": round(h2k4, 2)})
    derived["hammer2k_s"] = round(h2k4, 2)

    # ---- the full stress matrix × 4 policies, wavefront only ---------------
    results, walls, group_walls = run_stress_matrix()
    for name in TG.STRESS_SPECS:
        rows.append({
            "scale": f"stress:{name} (shape-group wall)",
            "engine": "wavefront",
            "policies": len(STRESS_POLICIES),
            "wall_s": round(walls[name], 2),
            "best_policy": STRESS_POLICIES[
                int(np.argmax(results[name]["ipc"]))].name,
        })
    derived["stress_total_s"] = round(sum(group_walls), 2)
    derived["stress_max_warps"] = max(
        s.n_warps for s in TG.STRESS_SPECS.values())
    derived["stress_scenarios"] = len(TG.STRESS_SPECS)
    return rows, derived
