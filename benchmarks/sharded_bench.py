"""Sharded-sweep benches (ISSUE 10 acceptance numbers).

Two sections:

  * ``sharded_parity`` — the correctness gate: the SAME experiments run
    unsharded and sharded over the process's device mesh, compared
    bitwise per (scenario, policy, seed, metric array). Covers the
    event engine (policy + seed sweep axes placed via ``device_put`` of
    the stacked inputs) and the wavefront engine (policy axis plus the
    in-kernel sharded-warp path). The derived ``parity_*_bitwise``
    booleans are what the tier2-sharded CI job gates on — derived
    within ONE run, never cross-run wall-clock.
  * ``sharded_stress`` — the scale demonstration: HAMMER16K (16384
    warps, 4x the unsharded stress matrix's ceiling) end to end
    through the api layer with the warp axis sharded over the full
    mesh, then the same spec on a single device asserting the sharded
    result stays bitwise identical at scale. Sized to one policy:
    virtual CPU devices share the host's cores, so warp-sharding buys
    no wall-clock locally — the point is that the placement compiles,
    runs, and changes nothing.

Both sections report ``{"skipped": True}`` when the process has fewer
than 2 jax devices; CI provides 8 virtual devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before the
first jax import).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.api import registry
from repro.core import baselines as BL
from repro.launch.mesh import make_local_mesh


def _bitwise(rs_a, rs_b) -> bool:
    """Every metric array of every (scenario, seed, policy) entry equal."""
    if (rs_a.scenarios != rs_b.scenarios
            or rs_a.policies != rs_b.policies):
        return False
    for name in rs_a.scenarios:
        for seed in rs_a.seeds(name):
            ma = rs_a.get(name, seed=seed)
            mb = rs_b.get(name, seed=seed)
            if set(ma) != set(mb):
                return False
            for k in ma:
                if not np.array_equal(np.asarray(ma[k]),
                                      np.asarray(mb[k]), equal_nan=True):
                    return False
    return True


def _mesh_shape(n_dev: int) -> Tuple[int, int]:
    """(data, model) over the largest power-of-two device count — the
    sweep-axis dimensions in play are all powers of two."""
    pow2 = 1 << (n_dev.bit_length() - 1)
    return (2, pow2 // 2) if pow2 >= 4 else (1, pow2)


def sharded_parity(quick: bool = False) -> Tuple[List[dict], Dict]:
    n_dev = len(jax.devices())
    if n_dev < 2:
        return [], {"skipped": True, "devices": n_dev,
                    "note": "needs >=2 devices; set XLA_FLAGS="
                            "--xla_force_host_platform_device_count=8"}
    data, model = _mesh_shape(n_dev)
    mesh = make_local_mesh(data, model)

    # event engine: 4-policy batch over (data); seed-stack over (model)
    wls = ("BFS", "SSSP") if quick else ("BFS", "SSSP", "BP", "CONS")
    ev = registry.paper_fig7(wls, seeds=(0, 1), name="sharded_parity_ev"
                             ).with_(policies=registry.STRESS_POLICIES)
    ev_sh = ev.with_(mesh=mesh, mesh_axes=("data", "model", None))

    # wavefront engine: policy axis over (data), warp axis over (model)
    ph = ("PHASED48",) if quick else ("PHASED48", "PHASED256")
    wf = registry.phased(ph, name="sharded_parity_wf")
    wf_sh = wf.with_(mesh=mesh, mesh_axes=("data", None, "model"))

    rows, derived = [], {"devices": n_dev,
                         "mesh": f"data={data} model={model}"}
    for tag, base, shard in (("event", ev, ev_sh),
                             ("wavefront", wf, wf_sh)):
        t0 = time.perf_counter()
        rs0 = base.run()
        w0 = time.perf_counter() - t0
        t0 = time.perf_counter()
        rs1 = shard.run()
        w1 = time.perf_counter() - t0
        ok = _bitwise(rs0, rs1)
        rows.append({"engine": tag, "scenarios": len(base.scenarios),
                     "policies": len(base.policies),
                     "wall_unsharded_s": round(w0, 3),
                     "wall_sharded_s": round(w1, 3),
                     "bitwise_equal": ok})
        derived[f"parity_{tag}_bitwise"] = ok
        c = shard.compile().calls[0]
        derived[f"plan_{tag}"] = (f"policy={c.policy_axes} "
                                  f"seed={c.seed_axes} warp={c.warp_axes}")
    return rows, derived


def sharded_stress(quick: bool = False) -> Tuple[List[dict], Dict]:
    n_dev = len(jax.devices())
    if n_dev < 2:
        return [], {"skipped": True, "devices": n_dev,
                    "note": "needs >=2 devices; set XLA_FLAGS="
                            "--xla_force_host_platform_device_count=8"}
    model = 1 << (n_dev.bit_length() - 1)        # warp axis gets it all
    mesh = make_local_mesh(1, model)
    exp = registry.stress_shard(scenarios=("HAMMER16K",),
                                policies=(BL.MEDIC,),
                                name="sharded_stress_16k")

    t0 = time.perf_counter()
    rs_sh = exp.with_(mesh=mesh,
                      mesh_axes=(None, None, "model")).run()
    wall_sh = time.perf_counter() - t0
    t0 = time.perf_counter()
    rs_1d = exp.run()
    wall_1d = time.perf_counter() - t0

    ipc = rs_1d.value("ipc", "HAMMER16K", policy="MeDiC")
    match = _bitwise(rs_1d, rs_sh)
    rows = [{"scenario": "HAMMER16K", "n_warps": 16384, "path": p,
             "wall_s": round(w, 2), "ipc": round(ipc, 6)}
            for p, w in ((f"warp-sharded over {model} devices", wall_sh),
                         ("single-device", wall_1d))]
    derived = {
        "devices": n_dev,
        "n_warps": 16384,
        "completed_16k": bool(np.isfinite(ipc)),
        "match_single_device_bitwise": match,
        "wall_sharded_s": round(wall_sh, 2),
        "wall_single_device_s": round(wall_1d, 2),
    }
    return rows, derived
