"""Trace-generation throughput benchmark (ISSUE 2 acceptance numbers).

Measures, at n_warps=1024:

  * the vectorized sampler generating the FULL 15-workload suite
    (``vec_suite_s`` — the wall-clock the tier-2 CI job budgets);
  * the loop reference generator on a sampled subset of workloads,
    extrapolated to the suite (``loop_suite_est_s`` — running all 15
    through the Python loop would take minutes, which is the point);
  * ``speedup_vs_loop`` = loop_suite_est_s / vec_suite_s (acceptance
    floor: >= 10x);
  * the stress scenario matrix (warps in the thousands) end to end.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

from repro.core import tracegen as TG
from repro.core import workloads as WL

SCALE_WARPS = 1024


def _scaled_specs() -> List[TG.TraceSpec]:
    return [dataclasses.replace(TG.TraceSpec.from_workload(s),
                                n_warps=SCALE_WARPS)
            for s in WL.WORKLOADS.values()]


def tracegen_scale(loop_sample: int = 1) -> Tuple[List[dict], Dict]:
    specs = _scaled_specs()
    rows = []

    t0 = time.perf_counter()
    batch = TG.generate_batch(specs, seeds=(0,))
    vec_suite_s = time.perf_counter() - t0
    cells = int(batch["lines"].size)
    rows.append({"path": "vectorized", "workloads": len(specs),
                 "n_warps": SCALE_WARPS, "cells": cells,
                 "wall_s": round(vec_suite_s, 3)})

    loop_s = 0.0
    for spec in specs[:loop_sample]:
        t0 = time.perf_counter()
        TG.generate_ref(spec, 0)
        loop_s += time.perf_counter() - t0
    loop_suite_est_s = loop_s / loop_sample * len(specs)
    rows.append({"path": "loop_ref", "workloads": loop_sample,
                 "n_warps": SCALE_WARPS,
                 "cells": cells // len(specs) * loop_sample,
                 "wall_s": round(loop_s, 3)})

    stress_s = {}
    for name, spec in TG.STRESS_SPECS.items():
        t0 = time.perf_counter()
        TG.generate(spec, 0)
        stress_s[name] = time.perf_counter() - t0
        rows.append({"path": f"stress:{name}", "workloads": 1,
                     "n_warps": spec.n_warps,
                     "cells": spec.n_instr * spec.n_warps
                     * spec.lines_per_instr,
                     "wall_s": round(stress_s[name], 3)})

    derived = {
        "vec_suite_s": round(vec_suite_s, 3),
        "vec_mcells_per_s": round(cells / vec_suite_s / 1e6, 1),
        "loop_sampled_workloads": loop_sample,
        "loop_suite_est_s": round(loop_suite_est_s, 1),
        "speedup_vs_loop": round(loop_suite_est_s / vec_suite_s, 1),
        "stress_matrix_s": round(sum(stress_s.values()), 3),
        "stress_max_warps": max(s.n_warps for s in
                                TG.STRESS_SPECS.values()),
    }
    return rows, derived
