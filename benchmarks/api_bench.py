"""API-layer overhead benchmark (ISSUE 4 acceptance numbers).

Measures what the declarative ``repro.api`` front door costs over the
raw imperative idiom it replaced, on the fig7 quick suite (4 workloads ×
the full fig7 policy batch, one trace shape).

``ResultSet`` times every emitted jitted call (``rs.wall_s`` — the raw
``simulate_sweep`` work, device sync included), so the api layer's own
cost is measured WITHIN one run as

    overhead_s = wall(Experiment.run()) - rs.wall_s

i.e. plan compile + trace materialization + dispatch bookkeeping +
result labeling. This within-run form is what the CI gate asserts
(``overhead_pct`` < 5%): it is robust to noisy shared runners, where
comparing two separate 15-second runs drifts by far more than 5% (the
raw-vs-api pair is still reported as context, unguarded).

Also records the plan metadata into the --json trajectory:
``plan_calls`` (one jitted call per (trace-shape, engine) bucket, so
this is also the bucket count) and ``plan_executables``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.engine_bench import block_tree
from repro import api
from repro.api import registry
from repro.core.simulator import simulate_sweep


def _raw_once(exp: api.Experiment) -> None:
    """The seed-era hand-rolled equivalent of ``exp.run()``: per shape
    bucket, stack every scenario's seed block and make one jitted call."""
    plan = exp.compile()
    for call in plan.calls:
        parts = [s.materialize() for s in call.scenarios]
        lines = np.concatenate([p["lines"] for p in parts])
        pcs = np.concatenate([p["pcs"] for p in parts])
        gap = np.concatenate([p["compute_gap"] for p in parts])
        (_, n_warps, lanes) = call.shape
        block_tree(simulate_sweep(lines, pcs, gap, exp.policies,
                                  n_warps=n_warps, lanes=lanes,
                                  prm=exp.prm, engine=call.engine,
                                  wave_size=call.wave_size))


def api_overhead(quick: bool = True, repeats: int = 2
                 ) -> Tuple[List[dict], Dict]:
    # quick is the gated configuration; the full suite is the same shape
    # bucket with 15 scenarios instead of 4
    exp = registry.PAPER_FIG7_QUICK if quick else registry.PAPER_FIG7

    t0 = time.perf_counter()
    plan = exp.compile()
    plan_compile_us = (time.perf_counter() - t0) * 1e6

    exp.run()                                   # warm the jit cache
    best = None
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        rs = exp.run()
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, rs)
    api_warm_s, rs = best
    sweep_warm_s = rs.wall_s                    # the raw jitted-call work
    overhead_s = api_warm_s - sweep_warm_s
    overhead_pct = overhead_s / sweep_warm_s * 100.0

    # context only (not gated): the hand-rolled path, one warm run —
    # subject to run-to-run machine noise
    t0 = time.perf_counter()
    _raw_once(exp)
    raw_warm_s = time.perf_counter() - t0

    rows = [{"path": "api Experiment.run", "scenarios": len(exp.scenarios),
             "policies": len(exp.policies), "wall_s": round(api_warm_s, 4)},
            {"path": "jitted calls within run",
             "scenarios": len(exp.scenarios),
             "policies": len(exp.policies),
             "wall_s": round(sweep_warm_s, 4)},
            {"path": "raw simulate_sweep (context)",
             "scenarios": len(exp.scenarios),
             "policies": len(exp.policies), "wall_s": round(raw_warm_s, 4)}]
    for c in plan.calls:
        i, w, l = c.shape
        rows.append({"path": f"plan call [{c.engine}] I={i} W={w} L={l}",
                     "scenarios": len(c.scenarios),
                     "policies": len(exp.policies), "wall_s": ""})
    derived = {
        "experiment": exp.name,
        "api_warm_s": round(api_warm_s, 4),
        "sweep_warm_s": round(sweep_warm_s, 4),
        "raw_warm_s": round(raw_warm_s, 4),
        "overhead_s": round(overhead_s, 4),
        "overhead_pct": round(overhead_pct, 3),
        "plan_compile_us": round(plan_compile_us, 1),
        # one jitted call per (trace-shape, engine) bucket by
        # construction, so this is also the bucket count
        "plan_calls": plan.n_calls,
        "plan_executables": plan.n_executables,
    }
    return rows, derived
