"""Emit the EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSONs produced by ``repro.launch.dryrun`` — plus, with ``--wavefront``,
op-level timings of the wavefront engine's per-wave work (ISSUE 6).

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
    PYTHONPATH=src python -m benchmarks.roofline --wavefront [--quick]

The wavefront mode times the three per-wave components in isolation —
wave selection (argsort vs top_k), the cache pass (ref lane scan vs the
fused one-sweep backend, plus a sub-attribution of the ref scan into
tag gather / RRIP+fill / EAF+PC updates / observe scatter), and the
timing pass (unfused ref vs fused scan recovery) — at W ∈ {48, 256,
1024, 4096}, which is how the fusion targets were ranked. JSON output
rides ``benchmarks/run.py --json --only roofline_wavefront``.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np


def load(dirpath):
    cells = []
    for fp in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fp) as f:
            cells.append(json.load(f))
    return cells


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    return f"{b/1e6:.1f}MB"


def emit(cells, mesh="16x16"):
    print(f"\n### Roofline table — mesh {mesh} (per device, per step)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPS | useful ratio | roofline frac | mem/dev | fits "
          "16GB |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            print(f"| {c['arch']} | {c['shape']} | — | — | — | skipped | — "
                  f"| — | — | — | — |")
            continue
        if c["status"] != "ok":
            print(f"| {c['arch']} | {c['shape']} | ERROR: "
                  f"{c.get('error','')[:60]} | | | | | | | | |")
            continue
        r = c["roofline"]
        m = c["memory"]
        print(f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3f} | "
              f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
              f"{r['dominant']} | {c['model_flops_global']:.2e} | "
              f"{c['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
              f"{fmt_bytes(m['per_device_live_bytes'])} | "
              f"{'yes' if m['fits_16gb'] else 'NO'} |")


def summarize(cells):
    ok = [c for c in cells if c["status"] == "ok"]
    sp = [c for c in ok if c["mesh"] == "16x16"]
    if not sp:
        return
    worst = min(sp, key=lambda c: c["roofline"]["roofline_fraction"])
    coll = max(sp, key=lambda c: c["roofline"]["collective_s"] /
               max(sum((c["roofline"]["compute_s"],
                        c["roofline"]["memory_s"],
                        c["roofline"]["collective_s"])), 1e-12))
    print("\n### Hillclimb candidates (single-pod)")
    print(f"- worst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"({worst['roofline']['roofline_fraction']:.4f})")
    print(f"- most collective-bound: {coll['arch']} x {coll['shape']} "
          f"(coll {coll['roofline']['collective_s']:.2f}s of "
          f"{coll['roofline']['compute_s']:.2f}s compute)")
    n_err = sum(1 for c in cells if c["status"] == "error")
    n_skip = sum(1 for c in cells if c["status"] == "skipped")
    print(f"\ncells: {len(cells)} total, {len(ok)} ok, {n_skip} skipped "
          f"(documented), {n_err} errors")


# ---------------------------------------------------------------------------
# --wavefront: op-level timing of the engine's per-wave components
# ---------------------------------------------------------------------------

_WF_WARPS = (48, 256, 1024, 4096)
_WF_WARPS_QUICK = (48, 256)


def _timed_us(fn, *args, reps: int = 5) -> float:
    """Warm mean wall-clock of a jitted fn, in microseconds."""
    import jax
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps * 1e6


def _wave_inputs(n_warps: int, lanes: int, prm, rng):
    """One synthetic wave at engine-realistic occupancy: B earliest-ready
    warps (sorted ready times), dense lane vectors, mixed hit/bypass/
    priority mix. Deterministic per W."""
    import jax.numpy as jnp
    from repro.core.engine import wavefront as WF
    B = WF.default_wave_size(n_warps)
    n = B * lanes
    ready = jnp.asarray(np.sort(rng.uniform(0, 50, n_warps)), jnp.float32)
    t_s = jnp.repeat(jnp.sort(ready)[:B], lanes) \
        + jnp.tile(jnp.arange(lanes, dtype=jnp.float32), B) * prm.lane_skew
    lines = jnp.asarray(rng.integers(0, 1 << 20, (B, lanes)), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.95)
    byp = jnp.asarray(rng.random(n) < 0.15) & valid
    hit = jnp.asarray(rng.random(n) < 0.4) & valid & ~byp
    hp = jnp.asarray(rng.random(n) < 0.5)
    return B, ready, t_s, lines, valid, byp, hit, hp


def wavefront_ops(quick: bool = False) -> Tuple[List[dict], Dict]:
    """Per-wave op-level timings: selection vs cache pass vs timing pass
    at each W. Every op is timed warm and in isolation under its own
    ``jax.jit`` (the engine inlines them into one jitted wave step, so
    these are attribution numbers, not additive wall-clock)."""
    import jax
    import jax.numpy as jnp
    from repro.core import baselines as BL
    from repro.core.engine import request as REQ
    from repro.core.engine import wavefront as WF
    from repro.core.engine.state import SimParams, init_state
    from repro.kernels.cache_pass import ops as CPASS
    from repro.kernels.cache_pass import ref as CREF
    from repro.kernels.wavefront_scan import ops as WSCAN
    from repro.kernels.wavefront_scan.ref import QueueCarry
    from repro.policy import ops as POL
    from repro.policy import to_arrays

    prm = SimParams()
    lanes = 16
    pa = to_arrays(BL.MEDIC)
    rows: List[dict] = []
    derived: Dict[str, object] = {}

    for n_warps in (_WF_WARPS_QUICK if quick else _WF_WARPS):
        rng = np.random.default_rng(n_warps)
        B, ready, t_s, lines, valid, byp, hit, hp = _wave_inputs(
            n_warps, lanes, prm, rng)
        tokens = POL.pcal_tokens(pa, n_warps)

        # ---- wave selection: full argsort vs top-B ------------------------
        sel_sort = jax.jit(lambda r: jnp.argsort(r)[:B])
        sel_topk = jax.jit(lambda r: jax.lax.top_k(-r, B)[1])
        t_sort = _timed_us(sel_sort, ready)
        t_topk = _timed_us(sel_topk, ready)

        # ---- cache pass: ref lane scan vs fused one-sweep -----------------
        st0 = init_state(n_warps, prm)
        w_sel = jnp.asarray(
            rng.choice(n_warps, size=B, replace=False), jnp.int32)
        pc_b = jnp.asarray(rng.integers(0, 64, B), jnp.int32)
        owt_b = jnp.zeros((B,), jnp.int32)
        slot_ok = jnp.ones((B,), bool)
        t0w = jnp.sort(ready)[:B]
        lines_lb = jnp.swapaxes(lines, 0, 1)

        def cache_fn(backend):
            def run(st, t0v, addr_lb):
                clf_b0 = jax.tree.map(lambda a: a[w_sel], st.clf)
                st, clf_b, recs = CPASS.wave_cache_pass(
                    st, clf_b0, tokens[w_sel], t0v, addr_lb, pc_b,
                    owt_b, slot_ok, prm, pa, backend=backend)
                st = st._replace(clf=jax.tree.map(
                    lambda full, b: full.at[w_sel].set(b), st.clf, clf_b))
                return st, recs
            return jax.jit(run)
        cargs = (st0, t0w, lines_lb)
        t_cache_ref = _timed_us(cache_fn("ref"), *cargs)
        t_cache_fused = _timed_us(cache_fn("fused"), *cargs)

        # ---- cache-pass sub-attribution: the ref scan's four stages -------
        # Each stage timed as its own L-lane scan carrying only that
        # mechanism's state — attribution of where the ref number goes,
        # not additive wall-clock.
        lane_ids = jnp.arange(lanes, dtype=jnp.int32)
        sidx_lb = REQ.set_index(lines_lb, prm)
        valid_lb = lines_lb >= 0

        @jax.jit
        def sub_tag_gather(tags, addr_lb):
            def step(t, xs):
                sidx, addr = xs
                tset = t[sidx]
                is_line = tset == addr[:, None]
                hit_way = jnp.argmax(is_line, axis=1)
                row = jnp.where(
                    jnp.arange(prm.ways)[None, :] == hit_way[:, None],
                    addr[:, None], tset)
                t = t.at[sidx].set(row, mode="drop")
                return t, jnp.any(is_line, axis=1)
            return jax.lax.scan(step, tags, (sidx_lb, addr_lb))

        @jax.jit
        def sub_rrip_fill(rrip, meta):
            def step(c, sidx):
                r, m = c
                rset = r[sidx]
                shift = prm.rrip_max - jnp.max(rset, axis=1)
                rset = rset + shift[:, None]
                victim = jnp.argmax(rset, axis=1)
                voh = jnp.arange(prm.ways)[None, :] == victim[:, None]
                r = r.at[sidx].set(jnp.where(voh, 0, rset), mode="drop")
                m = m.at[sidx].set(jnp.where(voh, 1, m[sidx]), mode="drop")
                return (r, m), victim
            return jax.lax.scan(step, (rrip, meta), sidx_lb)

        @jax.jit
        def sub_eaf_pc(eaf, pch, pca, pcr):
            def step(c, xs):
                e, h, a, q = c
                addr, v = xs
                eidx = REQ.eaf_index(addr, prm)
                e = e.at[jnp.where(v, eidx, prm.eaf_bits)].set(
                    1, mode="drop")
                pidx2 = REQ.pc_index(pc_b, prm)
                h = h.at[pidx2].add(v.astype(jnp.int32))
                a = a.at[pidx2].add(v.astype(jnp.int32))
                q = q.at[pidx2].add(v.astype(jnp.int32))
                return (e, h, a, q), None
            return jax.lax.scan(step, (eaf, pch, pca, pcr),
                                (lines_lb, valid_lb))

        @jax.jit
        def sub_observe(clf, addr_lb):
            clf_b = jax.tree.map(lambda a: a[w_sel], clf)

            def step(cb, addr):
                v = (addr >= 0).astype(jnp.int32)
                return CREF.observe_vec(cb, addr >= 0, v, v, prm, pa), None
            clf_b, _ = jax.lax.scan(step, clf_b, addr_lb)
            return jax.tree.map(lambda full, b: full.at[w_sel].set(b),
                                clf, clf_b)
        t_sub_tag = _timed_us(sub_tag_gather, st0.tags, lines_lb)
        t_sub_rrip = _timed_us(sub_rrip_fill, st0.rrip, st0.meta_type)
        t_sub_eaf = _timed_us(sub_eaf_pc, st0.eaf, st0.pc_hits,
                              st0.pc_acc, st0.pc_req)
        t_sub_obs = _timed_us(sub_observe, st0.clf, lines_lb)

        # ---- timing pass: unfused ref vs fused scan recovery --------------
        addr_s = jnp.repeat(lines, 1, axis=0).reshape(-1)
        bank = REQ.bank_index(addr_s, prm)
        ch = REQ.dram_channel(addr_s, prm)
        row = REQ.dram_row(addr_s, prm)
        use_l2 = valid & ~byp
        go_dram = valid & (byp | ~hit)
        carry = QueueCarry(
            bank_free=jnp.zeros((prm.banks,), jnp.float32),
            bank_ts=jnp.full((prm.banks,), -jnp.inf),
            hp_free=jnp.zeros((prm.dram_channels,), jnp.float32),
            hp_ts=jnp.full((prm.dram_channels,), -jnp.inf),
            hp_sa=jnp.full((prm.dram_channels,), -jnp.inf),
            lp_free=jnp.zeros((prm.dram_channels,), jnp.float32),
            lp_ts=jnp.full((prm.dram_channels,), -jnp.inf),
            lp_sa=jnp.full((prm.dram_channels,), -jnp.inf),
            cur_row=jnp.full((prm.dram_channels,), -1, jnp.int32))

        def timing_fn(backend):
            kw = dict(banks=prm.banks, channels=prm.dram_channels,
                      l2_svc=prm.l2_svc, l2_lat=prm.l2_lat,
                      occ_rowhit=prm.occ_rowhit,
                      occ_rowmiss=prm.occ_rowmiss, exact=False,
                      backend=backend)
            return jax.jit(lambda *a: WSCAN.wave_queue_recovery(*a, **kw))
        targs = (t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry)
        t_ref = _timed_us(timing_fn("ref"), *targs)
        t_fused = _timed_us(timing_fn("fused"), *targs)

        for op, us in (("select_argsort", t_sort), ("select_topk", t_topk),
                       ("cache_ref", t_cache_ref),
                       ("cache_fused", t_cache_fused),
                       ("cache_sub_tag_gather", t_sub_tag),
                       ("cache_sub_rrip_fill", t_sub_rrip),
                       ("cache_sub_eaf_pc", t_sub_eaf),
                       ("cache_sub_observe", t_sub_obs),
                       ("timing_ref", t_ref),
                       ("timing_fused", t_fused)):
            rows.append({"W": n_warps, "B": int(B), "op": op,
                         "wall_us": round(us, 1)})
        derived[f"timing_speedup_{n_warps}"] = round(t_ref / t_fused, 2)
        derived[f"select_speedup_{n_warps}"] = round(t_sort / t_topk, 2)
        derived[f"cache_speedup_{n_warps}"] = round(
            t_cache_ref / t_cache_fused, 2)
        biggest = max((("cache_ref", t_cache_ref), ("timing_ref", t_ref),
                       ("select_argsort", t_sort)), key=lambda kv: kv[1])
        derived[f"unfused_dominant_{n_warps}"] = biggest[0]
    return rows, derived


def emit_wavefront(rows, derived):
    print("\n### Wavefront per-wave op timings (warm, isolated jits)\n")
    print("| W | B | op | wall us |")
    print("|---|---|---|---|")
    for r in rows:
        print(f"| {r['W']} | {r['B']} | {r['op']} | {r['wall_us']} |")
    print()
    for k in sorted(derived):
        print(f"- {k}: {derived[k]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--wavefront", action="store_true",
                    help="time the wavefront engine's per-wave ops "
                         "instead of formatting dryrun tables")
    ap.add_argument("--quick", action="store_true",
                    help="--wavefront at W in {48, 256} only")
    args = ap.parse_args()
    if args.wavefront:
        emit_wavefront(*wavefront_ops(quick=args.quick))
        return
    cells = load(args.dir)
    emit(cells, "16x16")
    emit(cells, "2x16x16")
    summarize(cells)


if __name__ == "__main__":
    main()
