"""Emit the EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSONs produced by ``repro.launch.dryrun``.

    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath):
    cells = []
    for fp in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fp) as f:
            cells.append(json.load(f))
    return cells


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    return f"{b/1e6:.1f}MB"


def emit(cells, mesh="16x16"):
    print(f"\n### Roofline table — mesh {mesh} (per device, per step)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_FLOPS | useful ratio | roofline frac | mem/dev | fits "
          "16GB |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c["status"] == "skipped":
            print(f"| {c['arch']} | {c['shape']} | — | — | — | skipped | — "
                  f"| — | — | — | — |")
            continue
        if c["status"] != "ok":
            print(f"| {c['arch']} | {c['shape']} | ERROR: "
                  f"{c.get('error','')[:60]} | | | | | | | | |")
            continue
        r = c["roofline"]
        m = c["memory"]
        print(f"| {c['arch']} | {c['shape']} | {r['compute_s']:.3f} | "
              f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
              f"{r['dominant']} | {c['model_flops_global']:.2e} | "
              f"{c['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
              f"{fmt_bytes(m['per_device_live_bytes'])} | "
              f"{'yes' if m['fits_16gb'] else 'NO'} |")


def summarize(cells):
    ok = [c for c in cells if c["status"] == "ok"]
    sp = [c for c in ok if c["mesh"] == "16x16"]
    if not sp:
        return
    worst = min(sp, key=lambda c: c["roofline"]["roofline_fraction"])
    coll = max(sp, key=lambda c: c["roofline"]["collective_s"] /
               max(sum((c["roofline"]["compute_s"],
                        c["roofline"]["memory_s"],
                        c["roofline"]["collective_s"])), 1e-12))
    print("\n### Hillclimb candidates (single-pod)")
    print(f"- worst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"({worst['roofline']['roofline_fraction']:.4f})")
    print(f"- most collective-bound: {coll['arch']} x {coll['shape']} "
          f"(coll {coll['roofline']['collective_s']:.2f}s of "
          f"{coll['roofline']['compute_s']:.2f}s compute)")
    n_err = sum(1 for c in cells if c["status"] == "error")
    n_skip = sum(1 for c in cells if c["status"] == "skipped")
    print(f"\ncells: {len(cells)} total, {len(ok)} ok, {n_skip} skipped "
          f"(documented), {n_err} errors")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    cells = load(args.dir)
    emit(cells, "16x16")
    emit(cells, "2x16x16")
    summarize(cells)


if __name__ == "__main__":
    main()
