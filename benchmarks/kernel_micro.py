"""Kernel micro-benchmarks.

This container is CPU-only, so wall-clock numbers time the jitted pure-jnp
reference path (the math the Pallas kernels implement); the Pallas kernels
themselves are validated in interpret mode in tests and their VMEM/MXU
tiling is assessed structurally in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_micro():
    rng = np.random.default_rng(0)
    rows = []

    # flash attention ref path
    from repro.kernels.flash_attention.ref import flash_attention_ref
    b, s, h, hkv, d = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    us = _time(f, q, k, v)
    flops = 4 * b * s * s * h * d / 2
    rows.append({"name": "flash_attention_ref_512", "us_per_call": round(us, 1),
                 "derived": f"{flops/us/1e3:.1f} GFLOP/s-cpu"})

    # paged decode
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    bb, hkv2, g, d2, n, page, p = 8, 4, 4, 64, 64, 16, 16
    q2 = jnp.asarray(rng.standard_normal((bb, hkv2, g, d2)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n, page, hkv2, d2)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n, page, hkv2, d2)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, n, (bb, p)), jnp.int32)
    lens = jnp.full((bb,), p * page, jnp.int32)
    f2 = jax.jit(lambda *a: paged_decode_attention_ref(*a))
    us = _time(f2, q2, kp, vp, tbl, lens)
    rows.append({"name": "paged_decode_ref_b8_kv256", "us_per_call":
                 round(us, 1), "derived": f"{bb/(us/1e6):.0f} tok/s-cpu"})

    # rg_lru
    from repro.kernels.rg_lru.ref import rg_lru_ref
    a = jnp.asarray(rng.uniform(0.9, 0.999, (4, 256, 512)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 256, 512)), jnp.float32)
    h0 = jnp.zeros((4, 512), jnp.float32)
    f3 = jax.jit(lambda *args: rg_lru_ref(*args))
    us = _time(f3, a, x, h0)
    rows.append({"name": "rg_lru_ref_s256_w512", "us_per_call": round(us, 1),
                 "derived": f"{4*256*512/(us/1e6)/1e6:.0f} Melt/s-cpu"})

    # mlstm chunkwise
    from repro.models.xlstm import mlstm_chunkwise
    b3, s3, h3, dk, dv = 2, 256, 4, 32, 64
    q3 = jnp.asarray(rng.standard_normal((b3, s3, h3, dk)), jnp.float32)
    k3 = jnp.asarray(rng.standard_normal((b3, s3, h3, dk)), jnp.float32)
    v3 = jnp.asarray(rng.standard_normal((b3, s3, h3, dv)), jnp.float32)
    li = jnp.asarray(rng.standard_normal((b3, s3, h3)), jnp.float32)
    lf = jnp.log(jax.nn.sigmoid(
        jnp.asarray(rng.standard_normal((b3, s3, h3)) + 2, jnp.float32)))
    f4 = jax.jit(lambda *args: mlstm_chunkwise(*args)[0])
    us = _time(f4, q3, k3, v3, li, lf)
    rows.append({"name": "mlstm_chunkwise_s256", "us_per_call": round(us, 1),
                 "derived": f"{b3*s3/(us/1e6)/1e3:.0f} ktok/s-cpu"})

    # simulator throughput (requests/second through the DES)
    from repro.core import baselines as BL
    from repro.core import workloads as WL
    from repro.core.simulator import SimParams, simulate, simulate_sweep
    spec = WL.WORKLOADS["BP"]
    tr = WL.generate(spec, seed=0)
    args = (jnp.asarray(tr["lines"]), jnp.asarray(tr["pcs"]),
            jnp.asarray(tr["compute_gap"]))
    kw = dict(n_warps=spec.n_warps, lanes=spec.lines_per_instr,
              prm=SimParams())
    simulate(*args, pol=BL.MEDIC, **kw)["ipc"].block_until_ready()
    t0 = time.perf_counter()
    simulate(*args, pol=BL.MEDIC, **kw)["ipc"].block_until_ready()
    dt = time.perf_counter() - t0
    nreq = int((tr["lines"] >= 0).sum())
    rows.append({"name": "simulator_des", "us_per_call": round(dt * 1e6, 0),
                 "derived": f"{nreq/dt/1e3:.0f} kreq/s"})

    # vmapped policy sweep: all named policies in one jitted call
    pols = list(BL.ALL_NAMED)
    simulate_sweep(*args, pols, **kw)["ipc"].block_until_ready()
    t0 = time.perf_counter()
    simulate_sweep(*args, pols, **kw)["ipc"].block_until_ready()
    dt = time.perf_counter() - t0
    rows.append({"name": f"simulator_sweep_{len(pols)}pol",
                 "us_per_call": round(dt * 1e6, 0),
                 "derived": f"{len(pols)*nreq/dt/1e3:.0f} kreq/s"})
    return rows, {}
