"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the derived
headline numbers (harmonic-mean speedups etc.). Run:

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SUBSTR]
                                            [--json PATH]

``--json PATH`` additionally dumps a machine-readable record (one entry
per benchmark: wall time, rows, derived headline numbers) in the
``BENCH_*.json`` trajectory format, so perf can be tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _emit(name, rows, derived):
    print(f"\n## {name}")
    if rows:
        # union of keys, first-seen order — sections may mix row shapes
        # (e.g. engine_fused's scan_backend vs cache_backend A/B rows)
        keys = list(dict.fromkeys(k for r in rows for k in r))
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r.get(k, "-")) for k in keys))
    for k, v in derived.items():
        print(f"derived,{name}.{k},{v}")


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return str(v)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of workloads for a fast pass")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump per-benchmark us_per_call + derived numbers "
                         "to a BENCH_*.json-compatible file")
    args = ap.parse_args()
    if args.json:
        # fail fast on an unwritable path instead of after the full run,
        # without truncating an existing record or leaving a zero-byte
        # file behind if the run crashes before the final dump
        probe_created = not os.path.exists(args.json)
        with open(args.json, "a"):
            pass
        if probe_created:
            os.remove(args.json)

    from benchmarks import (api_bench, engine_bench, kernel_micro,
                            paper_figures, phased_bench, roofline,
                            serving_ab, sharded_bench, tracegen_bench)
    from repro.core import workloads as WL

    wls = ("BFS", "SSSP", "BP", "CONS") if args.quick else WL.WORKLOAD_NAMES

    benches = {
        "fig2_heterogeneity": lambda: paper_figures.fig2_heterogeneity(),
        "fig4_stability": lambda: paper_figures.fig4_stability(),
        "fig5_queueing": lambda: paper_figures.fig5_queueing(),
        "fig7_performance": lambda: paper_figures.fig7_performance(wls),
        "fig8_energy": lambda: paper_figures.fig8_energy(wls),
        "tracegen_scale": lambda: tracegen_bench.tracegen_scale(
            loop_sample=1 if args.quick else 3),
        "engine_scale": lambda: engine_bench.engine_scale(quick=args.quick),
        # in-run unfused-vs-fused wavefront A/B (ISSUE 6 acceptance:
        # fused_speedup_wide1k >= 1.5 at 1024 warps, same process)
        "engine_fused": lambda: engine_bench.fused_ab(quick=args.quick),
        # op-level attribution of the per-wave cost (selection vs cache
        # pass vs timing pass) behind roofline.py --wavefront
        "roofline_wavefront": lambda: roofline.wavefront_ops(
            quick=args.quick),
        # api-layer overhead is always measured on the quick suite (the
        # gated configuration); the full fig7 suite is the same single
        # shape bucket with more scenarios
        "api_overhead": lambda: api_bench.api_overhead(quick=True),
        # reclassification-lag vs oblivious-static-label IPC gap on the
        # drifting-regime specs, both directions: degrading PHASED_* +
        # recovery-shaped PHASED_RECOVER_* (quick: 48+256 warps; full
        # adds the 1k/2k sizes)
        "phased_gap": lambda: phased_bench.phased_gap(quick=args.quick),
        # multi-device sweep correctness + scale (--only sharded runs
        # both): in-run unsharded-vs-sharded bitwise parity on both
        # engines, then the 16k-warp warp-sharded stress demonstration;
        # each reports skipped=True without >=2 devices (tier2-sharded
        # provides 8 virtual devices via XLA_FLAGS)
        "sharded_parity": lambda: sharded_bench.sharded_parity(
            quick=args.quick),
        "sharded_stress": lambda: sharded_bench.sharded_stress(
            quick=args.quick),
        "serving_ab": serving_ab.serving_ab,
        # open-loop serving simulator A/B via the declarative registry
        # (--only serving runs both serving benches); carries the in-run
        # medic-vs-lru bursty p99 gate for the tier2-serving CI job
        "serving_sim": lambda: serving_ab.serving_sim(quick=args.quick),
        "kernel_micro": kernel_micro.kernel_micro,
    }
    t00 = time.time()
    record = {"schema": "bench-v1", "quick": args.quick, "benchmarks": []}
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        rows, derived = fn()
        us = (time.time() - t0) * 1e6
        print(f"{name},{us:.0f},rows={len(rows)}")
        _emit(name, rows, derived)
        record["benchmarks"].append({
            "name": name,
            "us_per_call": round(us),
            "n_rows": len(rows),
            "rows": [{k: _jsonable(v) for k, v in r.items()} for r in rows],
            "derived": {k: _jsonable(v) for k, v in derived.items()},
        })
        sys.stdout.flush()
    total = time.time() - t00
    record["total_wall_s"] = round(total, 1)
    print(f"\ntotal_wall_s,{total:.1f},")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
        print(f"json,{args.json},")


if __name__ == "__main__":
    main()
