"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the derived
headline numbers (harmonic-mean speedups etc.). Run:

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def _emit(name, rows, derived):
    print(f"\n## {name}")
    if rows:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    for k, v in derived.items():
        print(f"derived,{name}.{k},{v}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of workloads for a fast pass")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import kernel_micro, paper_figures, serving_ab
    from repro.core import workloads as WL

    wls = ("BFS", "SSSP", "BP", "CONS") if args.quick else WL.WORKLOAD_NAMES

    benches = {
        "fig2_heterogeneity": lambda: paper_figures.fig2_heterogeneity(),
        "fig4_stability": lambda: paper_figures.fig4_stability(),
        "fig5_queueing": lambda: paper_figures.fig5_queueing(),
        "fig7_performance": lambda: paper_figures.fig7_performance(wls),
        "fig8_energy": lambda: paper_figures.fig8_energy(wls),
        "serving_ab": serving_ab.serving_ab,
        "kernel_micro": kernel_micro.kernel_micro,
    }
    t00 = time.time()
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        rows, derived = fn()
        us = (time.time() - t0) * 1e6
        print(f"{name},{us:.0f},rows={len(rows)}")
        _emit(name, rows, derived)
        sys.stdout.flush()
    print(f"\ntotal_wall_s,{time.time()-t00:.1f},")


if __name__ == "__main__":
    main()
