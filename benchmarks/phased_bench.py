"""Phased scenario family: reclassification-lag vs oblivious-static-label
IPC gap (ISSUE 5 acceptance numbers).

Runs the ``PAPER_PHASED`` labeling ladder — Baseline, then MeDiC under
stale (classify once at phase 0, freeze), online (the paper's periodic
reclassification) and oracle (ground-truth per-phase labels) labeling —
on the drifting-regime ``PHASED_*`` specs, all four policies in one
vmapped wavefront call per trace shape.

The headline number per scenario is the **gap closure**

    closure = (ipc_online - ipc_stale) / (ipc_oracle - ipc_stale)

i.e. how much of the stale→oracle IPC gap online reclassification
recovers; ``1 - closure`` is the reclassification lag's cost. The
acceptance floor (closure ≥ 0.5 on at least one PHASED_* spec) is
asserted in-test (tests/test_golden_phased.py), NOT on wall-clock —
container timing is too noisy to gate on.

Since PR 7 the suite reports closure **per drift direction**: the
degrading ``PHASED_*`` specs (hit → miss, the PR 5 family) and the
recovery-shaped ``PHASED_RECOVER_*`` mirror (miss → hit), whose derived
keys carry a ``_recover`` suffix (``closure_recover[...]``,
``best_closure_recover``). The recovery direction is the one the
probe-ratchet fix unlocked: online labels must ratchet back UP off the
cache-path probe sample, which the pre-PR 7 classifier could not do.
Both directions run in ONE experiment (shared trace shapes bucket into
the same jitted calls, so ``n_calls`` stays at one per shape).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.api import registry

#: the ladder's policy names, in registry.PHASED_POLICIES order
STALE, ONLINE, FAST, ORACLE = ("MeDiC-stale", "MeDiC", "MeDiC-fast",
                               "MeDiC-oracle")


def gap_closure(ipc_stale: float, ipc_online: float,
                ipc_oracle: float) -> float:
    """Fraction of the stale→oracle IPC gap that online labeling closes."""
    gap = ipc_oracle - ipc_stale
    if abs(gap) < 1e-12:
        return float("nan")
    return (ipc_online - ipc_stale) / gap


def phased_gap(quick: bool = True) -> Tuple[List[dict], Dict]:
    from repro.api.experiment import Experiment
    deg = registry.PAPER_PHASED_QUICK if quick else registry.PAPER_PHASED
    rec = registry.PAPER_RECOVER_QUICK if quick else registry.PAPER_RECOVER
    # both drift directions in ONE experiment: a PHASED_* spec and its
    # PHASED_RECOVER_* mirror share the trace shape, so the plan
    # compiler buckets them into the same jitted calls — n_calls stays
    # at one per shape, same as the degrade-only suite
    exp = Experiment(deg.name, deg.scenarios + rec.scenarios,
                     deg.policies, engine=deg.engine)
    t0 = time.perf_counter()
    rs = exp.run()
    wall = time.perf_counter() - t0

    rows: List[dict] = []
    derived: Dict[str, float] = {}
    closures: Dict[str, List[float]] = {"": [], "_recover": []}
    recover_names = {s.name for s in rec.scenarios}
    for scen in [s.name for s in exp.scenarios]:
        direction = "_recover" if scen in recover_names else ""
        ipc = {pol.name: float(np.asarray(
            rs.value("ipc", scenario=scen, policy=pol.name, seed=0)))
            for pol in exp.policies}
        for pol, v in ipc.items():
            rows.append({"scenario": scen, "policy": pol,
                         "ipc": round(v, 6)})
        closures[direction] += [
            gap_closure(ipc[STALE], ipc[ONLINE], ipc[ORACLE]),
            gap_closure(ipc[STALE], ipc[FAST], ipc[ORACLE])]
        derived[f"closure[{scen}]"] = round(closures[direction][-2], 4)
        derived[f"closure_fast[{scen}]"] = round(closures[direction][-1], 4)
        derived[f"oracle_over_stale[{scen}]"] = round(
            ipc[ORACLE] / ipc[STALE], 4)
        derived[f"online_over_stale[{scen}]"] = round(
            ipc[ONLINE] / ipc[STALE], 4)
    # an online (non-oracle, non-stale) labeling's best recovery of the
    # stale->oracle gap anywhere in the suite, PER DRIFT DIRECTION —
    # ``best_closure`` is the ISSUE 5 floor (degrading drift),
    # ``best_closure_recover`` the ISSUE 7 floor (recovery drift).
    # NaN closures (a degenerate oracle==stale tie) must not poison the
    # max, hence the max over finite entries only
    for direction, cs in closures.items():
        finite = [c for c in cs if np.isfinite(c)]
        derived[f"best_closure{direction}"] = round(max(finite), 4) \
            if finite else float("nan")
    derived["suite_wall_s"] = round(wall, 2)
    derived["n_calls"] = rs.meta["n_calls"]
    return rows, derived
