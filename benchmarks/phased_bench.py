"""Phased scenario family: reclassification-lag vs oblivious-static-label
IPC gap (ISSUE 5 acceptance numbers).

Runs the ``PAPER_PHASED`` labeling ladder — Baseline, then MeDiC under
stale (classify once at phase 0, freeze), online (the paper's periodic
reclassification) and oracle (ground-truth per-phase labels) labeling —
on the drifting-regime ``PHASED_*`` specs, all four policies in one
vmapped wavefront call per trace shape.

The headline number per scenario is the **gap closure**

    closure = (ipc_online - ipc_stale) / (ipc_oracle - ipc_stale)

i.e. how much of the stale→oracle IPC gap online reclassification
recovers; ``1 - closure`` is the reclassification lag's cost. The
acceptance floor (closure ≥ 0.5 on at least one PHASED_* spec) is
asserted in-test (tests/test_golden_phased.py), NOT on wall-clock —
container timing is too noisy to gate on.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.api import registry

#: the ladder's policy names, in registry.PHASED_POLICIES order
STALE, ONLINE, FAST, ORACLE = ("MeDiC-stale", "MeDiC", "MeDiC-fast",
                               "MeDiC-oracle")


def gap_closure(ipc_stale: float, ipc_online: float,
                ipc_oracle: float) -> float:
    """Fraction of the stale→oracle IPC gap that online labeling closes."""
    gap = ipc_oracle - ipc_stale
    if abs(gap) < 1e-12:
        return float("nan")
    return (ipc_online - ipc_stale) / gap


def phased_gap(quick: bool = True) -> Tuple[List[dict], Dict]:
    exp = registry.PAPER_PHASED_QUICK if quick else registry.PAPER_PHASED
    t0 = time.perf_counter()
    rs = exp.run()
    wall = time.perf_counter() - t0

    rows: List[dict] = []
    derived: Dict[str, float] = {}
    closures: List[float] = []
    scenarios = [s.name for s in exp.scenarios]
    for scen in scenarios:
        ipc = {pol.name: float(np.asarray(
            rs.value("ipc", scenario=scen, policy=pol.name, seed=0)))
            for pol in exp.policies}
        for pol, v in ipc.items():
            rows.append({"scenario": scen, "policy": pol,
                         "ipc": round(v, 6)})
        closures += [gap_closure(ipc[STALE], ipc[ONLINE], ipc[ORACLE]),
                     gap_closure(ipc[STALE], ipc[FAST], ipc[ORACLE])]
        derived[f"closure[{scen}]"] = round(closures[-2], 4)
        derived[f"closure_fast[{scen}]"] = round(closures[-1], 4)
        derived[f"oracle_over_stale[{scen}]"] = round(
            ipc[ORACLE] / ipc[STALE], 4)
        derived[f"online_over_stale[{scen}]"] = round(
            ipc[ONLINE] / ipc[STALE], 4)
    # an online (non-oracle, non-stale) labeling's best recovery of the
    # stale->oracle gap anywhere in the suite — the ISSUE 5 floor.
    # NaN closures (a degenerate oracle==stale tie) must not poison the
    # max, hence nanmax over the finite entries
    finite = [c for c in closures if np.isfinite(c)]
    derived["best_closure"] = round(max(finite), 4) if finite \
        else float("nan")
    derived["suite_wall_s"] = round(wall, 2)
    derived["n_calls"] = rs.meta["n_calls"]
    return rows, derived
