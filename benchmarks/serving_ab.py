"""Altitude-B benchmarks: MeDiC pool manager vs LRU at the serving layer.

Two views of the same mechanism:

  * ``serving_ab``  — the real-data-path ``ServeEngine`` A/B (reduced
    decoder LM, KV blocks physically offloaded/restored), tiny scale;
  * ``serving_sim`` — the vectorized open-loop serving simulator driven
    through the declarative registry (``PAPER_SERVING(_QUICK)``):
    arrival-process scenarios × the pool-policy ladder, per-policy
    p99/goodput rows and the in-run MeDiC-vs-LRU tail-latency gate the
    tier2-serving CI job asserts on.
"""
from __future__ import annotations

from repro.configs.base import get_config
from repro.serving.engine import EngineConfig, run_ab
from repro.serving.pool import PoolConfig
from repro.serving.request import ServeWorkload


def serving_ab():
    cfg = get_config("qwen3_1_7b").reduced(num_layers=2)
    wl = ServeWorkload(n_requests=24)
    pool = PoolConfig(budget_blocks=48, block_tokens=16)
    out = run_ab(cfg, wl, pool, EngineConfig(max_slots=4, max_len=448),
                 seed=0)
    rows = []
    for policy in ("lru", "medic"):
        s = out[policy]
        rows.append({
            "policy": policy,
            "throughput_tok_per_step": round(s["throughput"], 4),
            "completed": s["completed"],
            "mean_latency_steps": round(s["mean_latency"], 1),
            "mean_ttft_steps": round(s["mean_ttft"], 1),
            "mean_queue_wait": round(s["mean_queue_wait"], 1),
            "mean_fetch_qdelay": round(s["mean_qdelay"], 2),
            "p99_fetch_qdelay": round(s["p99_qdelay"], 2),
            "bypassed_blocks": int(s["bypassed_blocks"]),
            "stall_steps": int(s["stall_steps"]),
            "fetches": int(s["fetches"]),
            "resident_blocks": int(s["resident_blocks"]),
        })
    derived = {
        "medic_throughput_gain": round(
            out["medic"]["throughput"] / max(out["lru"]["throughput"], 1e-9),
            3),
    }
    return rows, derived


def serving_sim(quick: bool = False):
    """Open-loop serving A/B through ``Scenario.serving`` + the registry.

    One row per (scenario, policy, seed) with the tail/goodput metrics;
    derived numbers carry the per-scenario MeDiC-vs-LRU p99 ratios plus
    the bursty-scenario gate value CI asserts in-run.
    """
    from repro.api import registry

    exp = registry.PAPER_SERVING_QUICK if quick else registry.PAPER_SERVING
    rs = exp.run()
    rows = []
    for r in rs.to_rows(metrics=(
            "completed", "steps", "p99_latency", "p99_latency_censored",
            "mean_latency", "mean_queue_wait", "p99_queue_wait",
            "mean_ttft", "goodput", "hit_ratio", "stall_steps",
            "bypassed_blocks", "eviction_churn", "max_concurrency")):
        rows.append({k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in r.items()})
    derived = {}
    for scen in rs.scenarios:
        for seed in rs.seeds(scen):
            lru = rs.value("p99_latency", scenario=scen,
                           policy="Baseline", seed=seed)
            med = rs.value("p99_latency", scenario=scen,
                           policy="MeDiC", seed=seed)
            derived[f"{scen}.s{seed}.medic_p99_over_lru"] = round(
                med / max(lru, 1e-9), 3)
    # the tier2-serving in-run gate: divergence-aware residency must not
    # lose the tail on the bursty scenario
    gs = "SERVE_BURSTY64"
    derived["bursty_gate_medic_p99_le_lru_p99"] = bool(
        rs.value("p99_latency", scenario=gs, policy="MeDiC", seed=0)
        <= rs.value("p99_latency", scenario=gs, policy="Baseline", seed=0))
    derived["wall_s"] = round(rs.wall_s, 2)
    return rows, derived
