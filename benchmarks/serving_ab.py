"""Altitude-B benchmark: MeDiC pool manager vs LRU on the serving engine."""
from __future__ import annotations

from repro.configs.base import get_config
from repro.serving.engine import EngineConfig, run_ab
from repro.serving.pool import PoolConfig
from repro.serving.request import ServeWorkload


def serving_ab():
    cfg = get_config("qwen3_1_7b").reduced(num_layers=2)
    wl = ServeWorkload(n_requests=24)
    pool = PoolConfig(budget_blocks=48, block_tokens=16)
    out = run_ab(cfg, wl, pool, EngineConfig(max_slots=4, max_len=448),
                 seed=0)
    rows = []
    for policy in ("lru", "medic"):
        s = out[policy]
        rows.append({
            "policy": policy,
            "throughput_tok_per_step": round(s["throughput"], 4),
            "completed": s["completed"],
            "mean_latency_steps": round(s["mean_latency"], 1),
            "mean_ttft_steps": round(s["mean_ttft"], 1),
            "mean_fetch_qdelay": round(s["mean_qdelay"], 2),
            "p99_fetch_qdelay": round(s["p99_qdelay"], 2),
            "bypassed_blocks": int(s["bypassed_blocks"]),
            "stall_steps": int(s["stall_steps"]),
            "fetches": int(s["fetches"]),
            "resident_blocks": int(s["resident_blocks"]),
        })
    derived = {
        "medic_throughput_gain": round(
            out["medic"]["throughput"] / max(out["lru"]["throughput"], 1e-9),
            3),
    }
    return rows, derived
