"""One benchmark per paper artefact (Figs 2/4/5/7/8) on the altitude-A
simulator, plus the altitude-B serving A/B and kernel micro-benchmarks.

Each function returns (rows, derived) where rows are CSV-able dicts.

All simulation goes through the declarative ``repro.api`` layer
(DESIGN.md §10): one single-scenario ``Experiment`` per (workload, seed
block, engine) — which the plan compiler lowers to exactly the
seed-stacked ``simulate_sweep`` call the seed-era code made by hand, so
the golden fig7 numbers are byte-identical — with results read back by
label through ``ResultSet`` instead of positional indexing.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro import api
from repro.api.registry import FIG7_SWEEP_POLICIES as SWEEP_POLICIES
from repro.core import baselines as BL
from repro.core import workloads as WL
from repro.core.simulator import Policy, SimParams

PRM = SimParams()

# default seed block swept TOGETHER with the policy batch: the scenario
# carries the whole block, so one jitted `simulate_sweep` call per
# workload covers policies x seeds.
FIG_SEEDS: Tuple[int, ...] = (0,)

_CACHE: Dict[Tuple[str, Tuple[int, ...], str],
             Dict[int, Dict[str, dict]]] = {}


def _result_dict(rs: api.ResultSet, workload: str, pol_name: str,
                 seed: int) -> dict:
    """One policy's metrics + the trace + the whole-sweep wall, in the
    dict shape the figure functions consume."""
    d = dict(rs.get(scenario=workload, policy=pol_name, seed=seed))
    d["sweep_wall_s"] = rs.wall_s     # wall time of the WHOLE sweep
    d["trace"] = rs.trace(workload, seed)
    return d


def _sweep(workload: str, seed: int = 0,
           seeds: Tuple[int, ...] = None,
           engine: str = "event") -> Dict[str, dict]:
    """All SWEEP_POLICIES on one workload, batched over policies and the
    seed block containing ``seed``. Returns name->metrics for ``seed``."""
    if seeds is None or seed not in seeds:
        seeds = FIG_SEEDS if seed in FIG_SEEDS else (seed,)
    key = (workload, seeds, engine)
    if key not in _CACHE:
        exp = api.Experiment(f"fig:{workload}",
                             (api.Scenario.workload(workload, seeds=seeds),),
                             SWEEP_POLICIES, engine=engine, prm=PRM)
        rs = exp.run(keep_traces=True)
        _CACHE[key] = {
            s: {pol.name: _result_dict(rs, workload, pol.name, s)
                for pol in SWEEP_POLICIES}
            for s in seeds}
    return _CACHE[key][seed]


_BY_NAME: Dict[str, Policy] = {p.name: p for p in SWEEP_POLICIES}
_OFF_SWEEP_CACHE: Dict[Tuple[str, Policy, int, str], dict] = {}


def _run(workload: str, pol: Policy, seed: int = 0,
         seeds: Tuple[int, ...] = None, engine: str = "event") -> dict:
    if _BY_NAME.get(pol.name) == pol:
        return _sweep(workload, seed, seeds, engine)[pol.name]
    # off-sweep policy (e.g. BL.RAND_SWEEP points): a one-policy
    # experiment — still no retrace, since the policy enters the jitted
    # computation as a traced pytree
    key = (workload, pol, seed, engine)
    if key not in _OFF_SWEEP_CACHE:
        exp = api.Experiment(
            f"fig:{workload}:{pol.name}",
            (api.Scenario.workload(workload, seeds=(seed,)),),
            (pol,), engine=engine, prm=PRM)
        rs = exp.run(keep_traces=True)
        _OFF_SWEEP_CACHE[key] = _result_dict(rs, workload, pol.name, seed)
    return _OFF_SWEEP_CACHE[key]


# ---------------------------------------------------------------------------
# Fig 2 — inter-warp hit-ratio heterogeneity
# ---------------------------------------------------------------------------

def fig2_heterogeneity(workloads=("BFS", "BP", "CONS")):
    rows = []
    for wl in workloads:
        out = _run(wl, BL.BASELINE)
        hr = out["warp_hit_ratio"]
        hist, edges = np.histogram(hr, bins=np.linspace(0, 1, 11))
        for lo, hi, n in zip(edges[:-1], edges[1:], hist):
            rows.append({"workload": wl, "hit_ratio_bin": f"{lo:.1f}-{hi:.1f}",
                         "n_warps": int(n)})
    spread = {wl: float(_run(wl, BL.BASELINE)["warp_hit_ratio"].std())
              for wl in workloads}
    return rows, {"hit_ratio_stddev": spread}


# ---------------------------------------------------------------------------
# Fig 4 — divergence stability over time
# ---------------------------------------------------------------------------

def fig4_stability(workload="BFS"):
    out = _run(workload, BL.BASELINE)
    rt = out["ratio_over_time"]          # [I, W]
    half = rt.shape[0] // 2
    a = rt[half - 8:half].mean(axis=0)
    b = rt[-8:].mean(axis=0)
    corr = float(np.corrcoef(a, b)[0, 1])
    rows = [{"workload": workload, "warp": int(w),
             "ratio_mid": float(a[w]), "ratio_end": float(b[w])}
            for w in range(0, rt.shape[1], 6)]
    return rows, {"half_to_half_correlation": corr}


# ---------------------------------------------------------------------------
# Fig 5 — L2 queueing-latency distribution
# ---------------------------------------------------------------------------

def fig5_queueing(workload="BFS"):
    out = _run(workload, BL.BASELINE)
    hist = out["qdelay_hist"]
    bins = ["0", "1", "2-3", "4-7", "8-15", "16-31", "32-63", "64-127",
            "128-255", "256-511", "512-1023", "1024+"]
    rows = [{"workload": workload, "queue_cycles": b, "requests": int(n)}
            for b, n in zip(bins, hist)]
    return rows, {"mean_qdelay_cycles": float(out["mean_qdelay"]),
                  "frac_over_64_cycles":
                      float(hist[7:].sum() / max(hist.sum(), 1))}


# ---------------------------------------------------------------------------
# Fig 7 — performance of MeDiC vs all baselines over 15 workloads
# ---------------------------------------------------------------------------

def fig7_performance(workloads=WL.WORKLOAD_NAMES, seeds=(0,),
                     engine="event"):
    """Speedup table. With several ``seeds`` the per-workload speedup is
    the mean over seeds, and every seed of a workload comes out of the
    same seed-stacked `simulate_sweep` call (tracegen.generate_batch).
    ``engine`` selects the simulation engine (the golden suite pins the
    default event path byte-identically; ``"wavefront"`` reproduces the
    orderings within the documented tolerance, DESIGN.md §9)."""
    seeds = tuple(seeds)
    policies = list(BL.ALL_NAMED)
    rows = []
    speedups: Dict[str, List[float]] = {p.name: [] for p in policies}
    speedups["Rand(ideal)"] = []
    for wl in workloads:
        per_pol: Dict[str, List[float]] = {p.name: [] for p in policies}
        ideal: List[float] = []
        for sd in seeds:
            base = float(_run(wl, BL.BASELINE, sd, seeds, engine)["ipc"])
            for pol in policies:
                per_pol[pol.name].append(
                    float(_run(wl, pol, sd, seeds, engine)["ipc"]) / base)
            # idealized Rand: best bypass probability per workload
            # (paper fn.3)
            ideal.append(max(
                float(_run(wl, BL.rand(p), sd, seeds, engine)["ipc"]) / base
                for p in (0.25, 0.5, 0.75)))
        for pol in policies:
            s = float(np.mean(per_pol[pol.name]))
            speedups[pol.name].append(s)
            rows.append({"workload": wl, "policy": pol.name,
                         "speedup": round(s, 4)})
        best = float(np.mean(ideal))
        speedups["Rand(ideal)"].append(best)
        rows.append({"workload": wl, "policy": "Rand(ideal)",
                     "speedup": round(best, 4)})

    def hmean(xs):
        xs = np.asarray(xs)
        return float(len(xs) / np.sum(1.0 / xs))

    derived = {f"hmean_speedup[{k}]": round(hmean(v), 4)
               for k, v in speedups.items()}
    derived["medic_vs_best_prior"] = round(
        hmean(speedups["MeDiC"]) / max(hmean(speedups["PCAL"]),
                                       hmean(speedups["EAF"]),
                                       hmean(speedups["PC-Byp"])), 4)
    if len(seeds) > 1:
        derived["n_seeds"] = len(seeds)
    return rows, derived


# ---------------------------------------------------------------------------
# Fig 8 — energy efficiency
# ---------------------------------------------------------------------------

def fig8_energy(workloads=WL.WORKLOAD_NAMES):
    rows = []
    ratios = []
    for wl in workloads:
        base = float(_run(wl, BL.BASELINE)["perf_per_energy"])
        med = float(_run(wl, BL.MEDIC)["perf_per_energy"])
        rows.append({"workload": wl, "policy": "MeDiC",
                     "perf_per_energy_vs_base": round(med / base, 4)})
        ratios.append(med / base)
    n = len(ratios)
    return rows, {"hmean_energy_eff_gain":
                  round(float(n / np.sum(1.0 / np.asarray(ratios))), 4)}
