"""Llama-3.2-Vision-11B backbone — text decoder with cross-attention image
layers every 5th layer; vision tower STUBBED (input_specs provides
precomputed patch embeddings). [hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    cross_attn_every=5,       # layers 4, 9, ... get cross-attention
    num_image_tokens=6400,    # 4 tiles x ~1600 patch embeddings (stub)
    rope_theta=500000.0,
)
