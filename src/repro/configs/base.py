"""Configuration system for MeDiC-JAX.

One ``ModelConfig`` per assigned architecture (``src/repro/configs/<id>.py``),
a shapes registry (train_4k / prefill_32k / decode_32k / long_500k), and
Train/Serve/Mesh configs. Everything is a frozen dataclass so configs are
hashable and usable as jit static arguments.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    ``family`` selects the block structure:
      dense   -- decoder-only transformer (GQA, optional SWA/qk-norm/bias)
      moe     -- dense skeleton with MoE FFN (top-k, capacity dispatch)
      hybrid  -- RecurrentGemma-style: RG-LRU blocks + local attention (1:2)
      ssm     -- xLSTM: alternating mLSTM / sLSTM blocks
      encdec  -- Whisper-style encoder-decoder (audio frontend stubbed)
      vlm     -- Llama-3.2-Vision-style: self-attn stack + interleaved
                 cross-attention to (stubbed) image patch embeddings
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA width; None = full attention
    rope_theta: float = 10000.0
    logit_softcap: Optional[float] = None

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # hybrid (RG-LRU)
    lru_width: int = 0
    conv1d_width: int = 4
    local_window: int = 2048
    block_pattern: Tuple[str, ...] = ()    # e.g. ("rec", "rec", "attn")

    # encoder-decoder
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0               # precomputed frame embeddings

    # vlm
    cross_attn_every: int = 0              # cross-attn layer every Nth layer
    num_image_tokens: int = 0

    # numerics / misc
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    remat: bool = True

    # MeDiC serving integration
    kv_block_size: int = 256               # paged-KV block granularity

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # Embedding tables are padded so the vocab axis shards over any mesh we
    # use (production model axis = 16) and stays MXU-aligned.
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def num_params(self) -> int:
        """Analytic parameter count (matches init_params; used for roofline)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    @property
    def num_active_params(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts with bounded state?"""
        if self.family in ("hybrid", "ssm"):
            return True
        return self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 4),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            lru_width=64 if self.lru_width else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2) if self.num_experts_per_tok else 0,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq_len=16 if self.encoder_seq_len else 0,
            num_image_tokens=16 if self.num_image_tokens else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            sliding_window=32 if self.sliding_window else None,
            local_window=16 if self.family == "hybrid" else self.local_window,
            kv_block_size=8,
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input-shape registry (assigned shapes; identical for all 10 archs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: 500k-token decode state is "
                       "unbounded; skipped per brief (see DESIGN.md §5)")
    return True, ""


# ---------------------------------------------------------------------------
# Train / serve / mesh configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" saves 4 bytes/param
    grad_compression: str = "none"    # "none" | "int8"


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    microbatches: int = 1             # gradient accumulation
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class MedicConfig:
    """MeDiC policy parameters (Fig 3 thresholds + sampling)."""
    mostly_hit_threshold: float = 0.7
    mostly_miss_threshold: float = 0.2
    sampling_interval: int = 1024       # accesses between re-classification
    enable_bypass: bool = True          # WByp
    enable_insertion: bool = True       # WIP
    enable_scheduler: bool = True       # WMS


ARCH_IDS = (
    "grok_1_314b",
    "olmoe_1b_7b",
    "recurrentgemma_2b",
    "h2o_danube_1_8b",
    "qwen1_5_110b",
    "qwen3_1_7b",
    "granite_3_8b",
    "whisper_tiny",
    "llama_3_2_vision_11b",
    "xlstm_125m",
)


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG
