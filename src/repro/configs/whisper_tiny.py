"""Whisper-tiny — encoder-decoder audio backbone; conv frontend STUBBED
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,             # decoder layers
    num_encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    encoder_seq_len=1500,     # precomputed mel-frame embeddings (stub)
    act="gelu",
)
