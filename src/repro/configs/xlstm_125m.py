"""xLSTM-125M — alternating sLSTM + mLSTM blocks. [arXiv:2405.04517]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                   # blocks carry their own projections
    vocab_size=50304,
    head_dim=192,
    act="gelu",
    block_pattern=("mlstm", "slstm"),
)
