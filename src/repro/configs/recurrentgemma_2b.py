"""RecurrentGemma-2B — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,        # MQA for the local-attention layers
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    lru_width=2560,
    conv1d_width=4,
    local_window=2048,
    block_pattern=("rec", "rec", "attn"),
    act="gelu",
    rope_theta=10000.0,
)
