"""Named experiment registry: the paper's evaluation as data.

The whole evaluation is two lines:

    from repro import api
    rows = api.registry.PAPER_FIG7.run().to_rows()

Following the tensor2tensor ``Problem``/registry idiom, experiments are
registered under string names (``api.registry.get("paper_fig7")``) so
harnesses select them by flag, and exposed as module constants for
direct import.

``FIG7_SWEEP_POLICIES`` is the canonical fig7 policy batch — every named
baseline plus the Rand(p) probe points the Rand(ideal) column derives
from — kept here so ``benchmarks/paper_figures.py`` and ad-hoc callers
share one definition.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.api.experiment import Experiment
from repro.api.scenario import Scenario
from repro.core import baselines as BL
from repro.core import tracegen as TG
from repro.core import workloads as WL
from repro.policy import Policy

#: every policy any paper figure needs, in one vmapped batch
FIG7_SWEEP_POLICIES: Tuple[Policy, ...] = tuple(BL.ALL_NAMED) + (
    BL.rand(0.25), BL.rand(0.5), BL.rand(0.75))

#: the stress-matrix comparison set — one policy per mechanism family
STRESS_POLICIES: Tuple[Policy, ...] = (BL.BASELINE, BL.PCAL, BL.WBYP,
                                       BL.MEDIC)

#: the phased-family labeling ladder: Baseline, then MeDiC with frozen
#: phase-0 labels (stale) / the paper's periodic reclassification
#: (online) / ground-truth per-phase labels (oracle) — one vmapped batch,
#: so the reclassification-lag IPC gap comes out of a single jitted call
PHASED_POLICIES: Tuple[Policy, ...] = BL.LABELING_LADDER

#: the serving A/B ladder: LRU (Baseline preset), MeDiC, and the stale /
#: oracle labeling variants — one simulator run per policy on the SAME
#: arrival stream
SERVING_POLICIES: Tuple[Policy, ...] = (BL.BASELINE, BL.MEDIC,
                                        BL.MEDIC_STALE, BL.MEDIC_ORACLE)

QUICK_WORKLOADS: Tuple[str, ...] = ("BFS", "SSSP", "BP", "CONS")
QUICK_PHASED: Tuple[str, ...] = ("PHASED48", "PHASED256")
QUICK_RECOVER: Tuple[str, ...] = ("PHASED_RECOVER48", "PHASED_RECOVER256")


def paper_fig7(workloads=WL.WORKLOAD_NAMES, seeds=(0,),
               engine: str = "event", name: str = "paper_fig7"
               ) -> Experiment:
    """The Fig 7 evaluation: workloads × (baselines + Rand probes).
    All 48-warp workloads share one trace shape, so the plan compiles
    to a single jitted call per engine."""
    return Experiment(
        name,
        tuple(Scenario.workload(w, seeds=seeds) for w in workloads),
        FIG7_SWEEP_POLICIES, engine=engine)


def stress(scenarios=tuple(TG.STRESS_SPECS), seeds=(0,),
           name: str = "stress") -> Experiment:
    """The 1k–4k-warp scheduler-stress matrix on the wavefront engine
    (the only engine that completes it) — one jitted call per distinct
    trace shape."""
    return Experiment(
        name,
        tuple(Scenario.stress(s, seeds=seeds) for s in scenarios),
        STRESS_POLICIES, engine="wavefront")


def stress_shard(scenarios=tuple(TG.SHARD_STRESS_SPECS), seeds=(0,),
                 policies=STRESS_POLICIES,
                 name: str = "stress_shard") -> Experiment:
    """The 16k–64k-warp sharded-sweep stress tier (``HAMMER16K`` /
    ``WIDE64K``) on the wavefront engine. Registered WITHOUT a mesh —
    a ``jax.sharding.Mesh`` holds concrete devices, so it cannot live in
    an import-time registry constant; attach one at run time, e.g.::

        from repro.launch.mesh import make_local_mesh
        rs = registry.STRESS_SHARD.with_(
            mesh=make_local_mesh(1, 8),
            mesh_axes=(None, None, "model")).run()

    (under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` the
    mesh is 8 virtual CPU devices — no TPU required). ``policies``
    trims the vmapped batch: the full 2-spec × 4-policy tier is a
    multi-hour run; ``benchmarks.sharded_bench`` demonstrates 16k
    warps on a single policy in minutes."""
    return Experiment(
        name,
        tuple(Scenario.stress(s, seeds=seeds) for s in scenarios),
        tuple(policies), engine="wavefront")


def phased(scenarios=tuple(TG.PHASED_SPECS), seeds=(0,),
           engine: str = "wavefront", name: str = "paper_phased"
           ) -> Experiment:
    """The drifting-regime suite: PHASED_* scenarios × the labeling
    ladder (stale / online / oracle MeDiC + Baseline). Runs on either
    engine (``.with_(engine=...)``); the wavefront default is what
    completes the 1k–2k-warp sizes."""
    return Experiment(
        name,
        tuple(Scenario.phased(s, seeds=seeds) for s in scenarios),
        PHASED_POLICIES, engine=engine)


def recover(scenarios=tuple(TG.PHASED_RECOVER_SPECS), seeds=(0,),
            engine: str = "wavefront", name: str = "paper_recover"
            ) -> Experiment:
    """The recovery-direction mirror of ``phased``: PHASED_RECOVER_*
    scenarios (miss -> mixed -> hit drift) × the same labeling ladder.
    Only meaningful since the PR 7 probe-ratchet fix — before it, online
    labels could not follow warps back up, so online degenerated to
    stale in this direction."""
    return Experiment(
        name,
        tuple(Scenario.phased(s, seeds=seeds) for s in scenarios),
        PHASED_POLICIES, engine=engine)


def serving(scenarios=("SERVE_POISSON64", "SERVE_BURSTY64",
                       "SERVE_DIURNAL64", "SERVE_POISSON2K"),
            seeds=(0,), policies=SERVING_POLICIES,
            name: str = "paper_serving") -> Experiment:
    """Open-loop serving A/Bs on the vectorized continuous-batching
    simulator: arrival-process scenarios × the LRU/MeDiC/stale/oracle
    pool-policy ladder. Every policy sees the identical request stream
    per (scenario, seed)."""
    return Experiment(
        name,
        tuple(Scenario.serving(s, seeds=seeds) for s in scenarios),
        tuple(policies), engine="serving")


PAPER_FIG7 = paper_fig7()
PAPER_FIG7_QUICK = paper_fig7(QUICK_WORKLOADS, name="paper_fig7_quick")
STRESS = stress()
STRESS_SHARD = stress_shard()
PAPER_PHASED = phased()
PAPER_PHASED_QUICK = phased(QUICK_PHASED, name="paper_phased_quick")
PAPER_RECOVER = recover()
PAPER_RECOVER_QUICK = recover(QUICK_RECOVER, name="paper_recover_quick")
PAPER_SERVING = serving()
PAPER_SERVING_QUICK = serving(("SERVE_POISSON64", "SERVE_BURSTY64"),
                              policies=(BL.BASELINE, BL.MEDIC),
                              name="paper_serving_quick")

EXPERIMENTS: Dict[str, Experiment] = {
    e.name: e for e in (PAPER_FIG7, PAPER_FIG7_QUICK, STRESS,
                        STRESS_SHARD, PAPER_PHASED, PAPER_PHASED_QUICK,
                        PAPER_RECOVER, PAPER_RECOVER_QUICK,
                        PAPER_SERVING, PAPER_SERVING_QUICK)}


def get(name: str) -> Experiment:
    if name not in EXPERIMENTS:
        raise KeyError(f"unknown experiment {name!r}; registered: "
                       f"{sorted(EXPERIMENTS)}")
    return EXPERIMENTS[name]
