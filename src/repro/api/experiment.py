"""Experiment: scenarios × policies × engine, compiled to a minimal Plan.

The declarative front door (DESIGN.md §10):

    exp  = Experiment("fig7", scenarios, policies, engine="event")
    plan = exp.compile()     # inspectable, no traces materialized yet
    rs   = plan.execute()    # == exp.run()

The **plan compiler** buckets scenarios by trace shape (I, W, L): every
scenario in a bucket rides the seed-stack axis of ONE jitted
``simulate_sweep`` call (policies vmapped on the leading axis), so the
whole experiment runs in exactly one call per (shape, engine) bucket —
the invariant the seed-era callers each re-implemented by hand.
Executables are further shared ACROSS buckets (and across experiments)
whenever the jit compile key — (shape, flat batch size, policy count,
engine, wave_size, scan_backend, cache_backend, SimParams) — agrees,
because ``simulate_sweep``'s
underlying jit cache is keyed on exactly those; the plan reports that
via ``n_executables``.

A single-scenario experiment lowers to the identical call the seed-era
positional idiom made (same trace arrays, same stacking), which is what
keeps the golden fig7 suite byte-identical through the migration.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import sharding as SH
from repro.api.results import ResultBlock, ResultSet
from repro.api.scenario import Scenario, Shape
from repro.core.engine import (SimParams, simulate_sweep,
                               validate_engine_args, validate_mesh_args)
from repro.policy import Policy

_TRACE_KEYS = ("lines", "pcs", "compute_gap", "archetype", "oracle_wtype")


@dataclasses.dataclass(frozen=True)
class PlanCall:
    """One emitted ``simulate_sweep`` call: a (shape, engine) bucket.
    Serving buckets run the (host-side, unjitted) serving simulator
    instead; their shape is ``(-1, max_slots, n_requests)``.

    ``mesh`` + the three axis fields are the bucket's RESOLVED
    multi-device placement (``None`` everywhere on single-device plans):
    the plan compiler applies the replication fallback per bucket —
    a seed-stack or warp count the mesh axis does not divide resolves
    to ``None`` here, so ``describe()`` and ``compile_key`` reflect
    what will actually shard, not what was asked for."""
    shape: Shape                       # (n_instr, n_warps, lines_per_instr)
    engine: str
    wave_size: Optional[int]
    scan_backend: str
    cache_backend: str
    scenarios: Tuple[Scenario, ...]    # seed blocks stack in this order
    mesh: Optional[object] = None      # jax.sharding.Mesh
    policy_axes: Optional[object] = None
    seed_axes: Optional[object] = None
    warp_axes: Optional[object] = None

    @property
    def flat(self) -> int:
        """Stacked trace count of the call (sum of scenario seed counts)."""
        return sum(s.n_seeds for s in self.scenarios)

    def compile_key(self, n_policies: int, prm: SimParams) -> tuple:
        """Everything ``simulate_sweep``'s jit cache keys on: two calls
        with equal keys share one compiled executable."""
        return (self.shape, self.flat, n_policies, self.engine,
                self.wave_size, self.scan_backend, self.cache_backend, prm,
                self.mesh, self.policy_axes, self.seed_axes,
                self.warp_axes)

    def execute_serving(self, exp: "Experiment") -> ResultBlock:
        """Run the serving simulator over this bucket: every (scenario,
        seed) request stream under every policy, metrics stacked to the
        standard ``[P, F]`` layout. One stream is generated per entry
        and shared across policies, so an A/B always compares on the
        IDENTICAL arrival sequence."""
        from repro.serving.sim import generate_serving, simulate_serving
        t0 = time.perf_counter()
        entries: List[Tuple[str, int]] = []
        cols: List[List[Dict[str, float]]] = []   # [F][P] metric dicts
        for s in self.scenarios:
            for seed in s.seeds:
                reqs = generate_serving(s.spec, seed)
                entries.append((s.name, seed))
                cols.append([simulate_serving(
                    reqs, s.spec, policy=pol,
                    pool_backend=exp.pool_backend)["metrics"]
                    for pol in exp.policies])
        metrics = {k: np.asarray(
            [[cols[f][p][k] for f in range(len(entries))]
             for p in range(len(exp.policies))], np.float64)
            for k in cols[0][0]}
        return ResultBlock(tuple(entries), metrics,
                           time.perf_counter() - t0)


@dataclasses.dataclass(frozen=True)
class Plan:
    """Compiled experiment: the minimal list of jitted calls to make."""
    experiment: "Experiment"
    calls: Tuple[PlanCall, ...]

    @property
    def n_calls(self) -> int:
        """Jitted calls to make — one per (trace-shape, engine) bucket,
        so this IS the bucket count."""
        return len(self.calls)

    @property
    def n_executables(self) -> int:
        """Distinct jit compile keys — calls beyond this reuse an
        executable compiled for an earlier bucket."""
        exp = self.experiment
        return len({c.compile_key(len(exp.policies), exp.prm)
                    for c in self.calls})

    def describe(self) -> str:
        exp = self.experiment
        lines = [f"plan[{exp.name}]: {len(exp.scenarios)} scenarios x "
                 f"{len(exp.policies)} policies -> {self.n_calls} call(s), "
                 f"{self.n_executables} executable(s)"]
        for c in self.calls:
            i, w, l = c.shape
            names = ", ".join(f"{s.name}x{s.n_seeds}" for s in c.scenarios)
            if c.engine == "serving":
                lines.append(f"  [serving] slots={w} requests={l} "
                             f"flat={c.flat}: {names}")
            else:
                shard = ""
                if c.mesh is not None:
                    shard = (f" sharded(policy={c.policy_axes} "
                             f"seed={c.seed_axes} warp={c.warp_axes})")
                lines.append(f"  [{c.engine}] shape I={i} W={w} L={l} "
                             f"flat={c.flat}{shard}: {names}")
        return "\n".join(lines)

    def execute(self, keep_traces: bool = False) -> ResultSet:
        """Materialize traces and run every planned call."""
        exp = self.experiment
        blocks: List[ResultBlock] = []
        for call in self.calls:
            if call.engine == "serving":
                blocks.append(call.execute_serving(exp))
                continue
            n_instr, n_warps, lanes = call.shape
            parts = [s.materialize() for s in call.scenarios]
            # a bucket may mix constant-intensity scenarios (scalar gap
            # per seed, [S]) with phased ones ([S, I]): broadcast the
            # scalars so the stacked axis is uniform
            if any(p["compute_gap"].ndim == 2 for p in parts):
                for p in parts:
                    g = p["compute_gap"]
                    if g.ndim == 1:
                        p["compute_gap"] = np.broadcast_to(
                            g[:, None], (g.shape[0], n_instr))
            tr = {k: np.concatenate([p[k] for p in parts])
                  for k in _TRACE_KEYS}
            t0 = time.perf_counter()
            out = simulate_sweep(
                np.asarray(tr["lines"]), np.asarray(tr["pcs"]),
                np.asarray(tr["compute_gap"]), exp.policies,
                n_warps=n_warps, lanes=lanes, prm=exp.prm,
                engine=call.engine, wave_size=call.wave_size,
                scan_backend=call.scan_backend,
                cache_backend=call.cache_backend,
                oracle_types=np.asarray(tr["oracle_wtype"]),
                mesh=call.mesh, policy_axes=call.policy_axes,
                seed_axes=call.seed_axes, warp_axes=call.warp_axes)
            out = {k: np.asarray(v) for k, v in out.items()}  # [P, F, ...]
            wall = time.perf_counter() - t0
            entries = tuple((s.name, seed) for s in call.scenarios
                            for seed in s.seeds)
            traces = None
            if keep_traces:
                traces = tuple(
                    {k: tr[k][f] for k in _TRACE_KEYS}
                    for f in range(call.flat))
            blocks.append(ResultBlock(entries, out, wall, traces))
        meta = {"experiment": exp.name, "engine": exp.engine,
                "n_calls": self.n_calls,
                "n_executables": self.n_executables}
        return ResultSet([p.name for p in exp.policies], blocks, meta)


@dataclasses.dataclass(frozen=True)
class Experiment:
    """Scenarios × policies × engine options — the one front door.

    ``run()`` compiles the plan and executes it; ``compile()`` exposes
    the plan for inspection (bucketing, call count, executable reuse)
    without materializing any traces.
    """
    name: str
    scenarios: Tuple[Scenario, ...]
    policies: Tuple[Policy, ...]
    engine: str = "event"
    wave_size: Optional[int] = None
    #: wavefront timing-pass backend (repro.kernels.wavefront_scan);
    #: "auto" = fused lax scans on CPU, Pallas kernel on TPU
    scan_backend: str = "auto"
    #: wavefront cache-pass backend (repro.kernels.cache_pass);
    #: "auto" = fused one-sweep on CPU, Pallas kernel on TPU
    cache_backend: str = "auto"
    #: serving-engine pool-transaction backend (engine="serving" only);
    #: "auto"/"fast" = vectorized access_batch, "ref" = sequential per-key
    pool_backend: str = "auto"
    #: device mesh for multi-device sweeps (``jax.sharding.Mesh``, e.g.
    #: ``launch.mesh.make_local_mesh``); None = single-device execution.
    #: Every (policy, seed) cell is an independent simulation, so the
    #: sharded run is bitwise-identical to the single-device one.
    mesh: Optional[object] = None
    #: (policy, seed, warp) mesh-axis assignment — which mesh axes the
    #: stacked policy axis, the seed-stack axis and (wavefront only) the
    #: engine-internal warp axis shard over. Entries are None, an axis
    #: name, or a tuple of names; an axis that does not divide its
    #: dimension falls back to replication per bucket. Defaults (when a
    #: mesh is given) to the mesh's first two axis names for (policy,
    #: seed) and no warp sharding.
    mesh_axes: Optional[Tuple] = None
    prm: SimParams = SimParams()

    def __post_init__(self):
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "policies", tuple(self.policies))
        if not self.scenarios:
            raise ValueError(f"experiment {self.name!r}: needs >= 1 "
                             "scenario")
        if not self.policies:
            raise ValueError(f"experiment {self.name!r}: needs >= 1 policy")
        names = [s.name for s in self.scenarios]
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            raise ValueError(f"experiment {self.name!r}: duplicate scenario "
                             f"names {sorted(dupes)} — results would "
                             "collide; pass name= to disambiguate")
        pnames = [p.name for p in self.policies]
        pdupes = {n for n in pnames if pnames.count(n) > 1}
        if pdupes:
            raise ValueError(f"experiment {self.name!r}: duplicate policy "
                             f"names {sorted(pdupes)}")
        if self.mesh_axes is not None and self.mesh is None:
            raise ValueError(f"experiment {self.name!r}: mesh_axes given "
                             "without a mesh; pass mesh= as well")
        if self.mesh is not None:
            if self.engine == "serving":
                raise ValueError(
                    f"experiment {self.name!r}: engine='serving' runs "
                    "host-side and does not take a mesh")
            axes = self.mesh_axes
            if axes is None:
                names = tuple(self.mesh.axis_names)
                axes = (names[0], names[1] if len(names) > 1 else None,
                        None)
            axes = tuple(axes) + (None,) * (3 - len(axes))
            if len(axes) != 3:
                raise ValueError(
                    f"experiment {self.name!r}: mesh_axes must be up to "
                    "3 entries (policy, seed, warp); got "
                    f"{self.mesh_axes!r}")
            object.__setattr__(self, "mesh_axes", axes)
            validate_mesh_args(self.mesh, *axes, engine=self.engine)
        serving = [s.name for s in self.scenarios if s.is_serving]
        if self.engine == "serving":
            if len(serving) != len(self.scenarios):
                raise ValueError(
                    f"experiment {self.name!r}: engine='serving' takes "
                    "only serving scenarios (Scenario.serving)")
            from repro.serving.sim.step import POOL_BACKENDS
            if self.pool_backend not in POOL_BACKENDS:
                raise ValueError(
                    f"experiment {self.name!r}: unknown pool_backend "
                    f"{self.pool_backend!r}; choose from {POOL_BACKENDS}")
        else:
            if serving:
                raise ValueError(
                    f"experiment {self.name!r}: serving scenarios "
                    f"{serving} need engine='serving'")
            validate_engine_args(self.engine, self.wave_size,
                                 self.scan_backend, self.cache_backend)

    def compile(self) -> Plan:
        """Bucket scenarios by trace shape; one PlanCall per bucket.

        With a mesh, each bucket's placement is resolved here (the
        replication fallback applied against the bucket's concrete
        policy count / seed-stack size / warp count), so the emitted
        plan is inspectable: ``describe()`` shows exactly which axes of
        which bucket will shard."""
        buckets: Dict[Shape, List[Scenario]] = {}
        for s in self.scenarios:
            buckets.setdefault(s.shape, []).append(s)
        calls = []
        for shape, scens in buckets.items():
            mesh = pol_ax = seed_ax = warp_ax = None
            if self.mesh is not None and self.engine != "serving":
                mesh = self.mesh
                p_want, s_want, w_want = self.mesh_axes
                flat = sum(s.n_seeds for s in scens)
                pol_ax = SH.resolve_axes(mesh, p_want, len(self.policies))
                seed_ax = SH.resolve_axes(mesh, s_want, flat)
                warp_ax = SH.resolve_axes(mesh, w_want, shape[1])
            calls.append(
                PlanCall(shape, self.engine, self.wave_size,
                         self.scan_backend, self.cache_backend,
                         tuple(scens), mesh, pol_ax, seed_ax, warp_ax))
        return Plan(self, tuple(calls))

    def run(self, keep_traces: bool = False) -> ResultSet:
        return self.compile().execute(keep_traces=keep_traces)

    # convenience for quick derivative experiments
    def with_(self, **changes) -> "Experiment":
        return dataclasses.replace(self, **changes)


def run(scenarios: Sequence[Scenario], policies: Sequence[Policy],
        engine: str = "event", wave_size: Optional[int] = None,
        scan_backend: str = "auto", cache_backend: str = "auto",
        prm: SimParams = SimParams(), mesh=None, mesh_axes=None,
        name: str = "adhoc", keep_traces: bool = False) -> ResultSet:
    """One-shot helper: ``api.run(scenarios, policies)`` -> ResultSet."""
    return Experiment(name, tuple(scenarios), tuple(policies), engine,
                      wave_size, scan_backend, cache_backend,
                      mesh=mesh, mesh_axes=mesh_axes,
                      prm=prm).run(keep_traces=keep_traces)
