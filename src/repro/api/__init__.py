"""Declarative experiment API (ISSUE 4 tentpole) — DESIGN.md §10.

One front door for every sweep:

    Scenario   what to simulate   (named, hashable; lowers via tracegen)
    Experiment scenarios × policies × engine; ``compile()`` -> Plan
    Plan       the minimal set of jitted ``simulate_sweep`` calls
               (one per (trace-shape, engine) bucket, policies vmapped,
               scenarios/seeds stacked on the flat axis)
    ResultSet  labeled results: ``.sel()``, ``.speedup_over()``,
               ``.to_rows()`` / ``.to_json()`` instead of positional
               ``v[0]``/``v[1]`` indexing
    registry   the paper suites as data: ``registry.PAPER_FIG7``,
               ``registry.STRESS``

``simulate`` / ``simulate_sweep`` stay available as the thin imperative
facades underneath; the golden fig7 suite pins that this layer is a
byte-identical re-expression of them.
"""
from repro.api import registry
from repro.api.experiment import Experiment, Plan, PlanCall, run
from repro.api.results import ResultBlock, ResultSet
from repro.api.scenario import Scenario

__all__ = [
    "Experiment", "Plan", "PlanCall", "ResultBlock", "ResultSet",
    "Scenario", "registry", "run",
]
