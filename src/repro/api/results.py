"""ResultSet: labeled experiment results with axis metadata.

The raw output of a plan execution is, per emitted call, a metrics dict
of arrays with leading axes ``[P, F, ...]`` (policy × flat trace index).
``ResultSet`` keeps those blocks and adds the labels — which (scenario,
seed) each flat index is, which policy each row is — so callers select
by name instead of positional ``v[0]``/``v[1]`` indexing:

    rs.get(scenario="BFS", policy="MeDiC", seed=0)["ipc"]
    rs.sel(policy="MeDiC").to_rows()
    rs.speedup_over("Baseline")["BFS"]["MeDiC"]
    rs.to_json()

Per-entry metric arrays keep their trailing shape (per-warp vectors,
histograms, time series); ``to_rows``/``to_json`` export the scalar
metrics by default.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ResultBlock:
    """Results of ONE emitted simulate_sweep call.

    ``entries[f]`` labels flat index ``f`` as (scenario name, seed);
    ``metrics`` maps metric name to an array ``[P, F, ...]``; ``wall_s``
    is the wall-clock of the whole call (compile included on the first
    run); ``traces[f]`` optionally keeps the input trace arrays.
    """
    entries: Tuple[Tuple[str, int], ...]
    metrics: Dict[str, np.ndarray]
    wall_s: float
    traces: Optional[Tuple[Dict[str, np.ndarray], ...]] = None


class ResultSet:
    """Labeled results over the (scenario, policy, seed) axes.

    ``sel(...)`` pins axes and returns a restricted view; ``get(...)``
    resolves one (scenario, seed) entry — with ``policy`` given it
    returns per-metric arrays for that policy, otherwise arrays keep
    their leading policy axis (ordered as ``self.policies``).
    """

    def __init__(self, policies: Sequence[str],
                 blocks: Sequence[ResultBlock],
                 meta: Optional[dict] = None,
                 _sel: Optional[dict] = None):
        self._policies = tuple(policies)
        self._blocks = tuple(blocks)
        self.meta = dict(meta or {})
        self._sel = dict(_sel or {})
        self._index: Dict[Tuple[str, int], Tuple[int, int]] = {}
        for bi, blk in enumerate(self._blocks):
            for fi, key in enumerate(blk.entries):
                if key in self._index:
                    raise ValueError(f"duplicate result entry {key}")
                self._index[key] = (bi, fi)

    # -- axes ---------------------------------------------------------------

    @property
    def policies(self) -> Tuple[str, ...]:
        if "policy" in self._sel:
            return (self._sel["policy"],)
        return self._policies

    @property
    def scenarios(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for name, _ in self._entries():
            if name not in seen:
                seen.append(name)
        return tuple(seen)

    def seeds(self, scenario: str) -> Tuple[int, ...]:
        return tuple(s for n, s in self._entries() if n == scenario)

    @property
    def metrics(self) -> Tuple[str, ...]:
        return tuple(self._blocks[0].metrics) if self._blocks else ()

    def scalar_metrics(self) -> Tuple[str, ...]:
        """Metrics that are one number per (scenario, policy, seed)."""
        if not self._blocks:
            return ()
        return tuple(k for k, v in self._blocks[0].metrics.items()
                     if v.ndim == 2)

    def _entries(self):
        for blk in self._blocks:
            for name, seed in blk.entries:
                if "scenario" in self._sel and name != self._sel["scenario"]:
                    continue
                if "seed" in self._sel and seed != self._sel["seed"]:
                    continue
                yield (name, seed)

    # -- selection ----------------------------------------------------------

    def sel(self, scenario: Optional[str] = None,
            policy: Optional[str] = None,
            seed: Optional[int] = None) -> "ResultSet":
        """Pin axes by label; returns a restricted view (no copy)."""
        new = dict(self._sel)
        if scenario is not None:
            if scenario not in {n for n, _ in self._entries()}:
                raise KeyError(f"unknown scenario {scenario!r}; have "
                               f"{self.scenarios}")
            new["scenario"] = scenario
        if policy is not None:
            if policy not in self._policies:
                raise KeyError(f"unknown policy {policy!r}; have "
                               f"{self._policies}")
            new["policy"] = policy
        if seed is not None:
            if int(seed) not in {s for _, s in self._entries()}:
                raise KeyError(f"unknown seed {seed!r}; have "
                               f"{sorted({s for _, s in self._entries()})}")
            new["seed"] = int(seed)
        return ResultSet(self._policies, self._blocks, self.meta, new)

    def _resolve(self, scenario, seed) -> Tuple[str, int]:
        scenario = scenario if scenario is not None \
            else self._sel.get("scenario")
        seed = seed if seed is not None else self._sel.get("seed")
        entries = list(self._entries())
        names = {n for n, _ in entries}
        if scenario is None:
            if len(names) != 1:
                raise KeyError(f"ambiguous scenario; specify one of "
                               f"{sorted(names)}")
            scenario = next(iter(names))
        elif scenario not in names:
            raise KeyError(f"unknown scenario {scenario!r}; have "
                           f"{sorted(names)}")
        if seed is None:
            sds = [s for n, s in entries if n == scenario]
            if len(sds) != 1:
                raise KeyError(f"ambiguous seed for {scenario!r}; "
                               f"specify one of {sds}")
            seed = sds[0]
        return scenario, int(seed)

    def get(self, scenario: Optional[str] = None,
            policy: Optional[str] = None,
            seed: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Metrics of one (scenario, seed) entry. With ``policy`` (or a
        pinned policy) the leading policy axis is resolved too; otherwise
        every metric keeps it (ordered as ``self.policies``)."""
        scenario, seed = self._resolve(scenario, seed)
        key = (scenario, seed)
        if key not in self._index:
            raise KeyError(f"no results for scenario={scenario!r} "
                           f"seed={seed}")
        bi, fi = self._index[key]
        blk = self._blocks[bi]
        policy = policy if policy is not None else self._sel.get("policy")
        if policy is None:
            return {k: v[:, fi] for k, v in blk.metrics.items()}
        if policy not in self._policies:
            raise KeyError(f"unknown policy {policy!r}; have "
                           f"{self._policies}")
        pi = self._policies.index(policy)
        return {k: v[pi, fi] for k, v in blk.metrics.items()}

    def value(self, metric: str, scenario: Optional[str] = None,
              policy: Optional[str] = None,
              seed: Optional[int] = None):
        """One metric of one entry, as a float when it is scalar."""
        out = self.get(scenario, policy, seed)[metric]
        return float(out) if np.ndim(out) == 0 else out

    def trace(self, scenario: Optional[str] = None,
              seed: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Input trace arrays of one entry (needs run(keep_traces=True))."""
        scenario, seed = self._resolve(scenario, seed)
        bi, fi = self._index[(scenario, seed)]
        blk = self._blocks[bi]
        if blk.traces is None:
            raise ValueError("traces were not kept; pass keep_traces=True "
                             "to Experiment.run / Plan.execute")
        return blk.traces[fi]

    # -- derived ------------------------------------------------------------

    def speedup_over(self, base: str = "Baseline", metric: str = "ipc",
                     reduce: Optional[str] = "mean"
                     ) -> Dict[str, Dict[str, float]]:
        """Per-scenario, per-policy speedup vs the ``base`` policy.

        Ratios are computed per seed (each seed's own baseline), then
        reduced over seeds (``reduce="mean"``; ``reduce=None`` keeps the
        per-seed list). Returns ``{scenario: {policy: value}}``.
        """
        if base not in self._policies:
            raise KeyError(f"unknown base policy {base!r}")
        bi_p = self._policies.index(base)
        out: Dict[str, Dict[str, List[float]]] = {}
        for name, seed in self._entries():
            bidx, fi = self._index[(name, seed)]
            m = self._blocks[bidx].metrics[metric]
            denom = float(m[bi_p, fi])
            per = out.setdefault(name, {p: [] for p in self.policies})
            for p in self.policies:
                per[p].append(float(m[self._policies.index(p), fi]) / denom)
        if reduce is None:
            return out
        if reduce != "mean":
            raise ValueError(f"unknown reduce {reduce!r}")
        return {n: {p: float(np.mean(v)) for p, v in per.items()}
                for n, per in out.items()}

    # -- export -------------------------------------------------------------

    def to_rows(self, metrics: Optional[Sequence[str]] = None
                ) -> List[dict]:
        """Flat labeled rows, one per (scenario, policy, seed): the
        replacement for positional ``v[0]``/``v[1]`` slicing. Non-scalar
        metrics are skipped unless named explicitly (then exported as
        lists)."""
        cols = tuple(metrics) if metrics is not None \
            else self.scalar_metrics()
        rows = []
        for name, seed in self._entries():
            bi, fi = self._index[(name, seed)]
            blk = self._blocks[bi]
            for p in self.policies:
                pi = self._policies.index(p)
                row = {"scenario": name, "policy": p, "seed": seed}
                for k in cols:
                    v = blk.metrics[k][pi, fi]
                    row[k] = float(v) if np.ndim(v) == 0 \
                        else np.asarray(v).tolist()
                rows.append(row)
        return rows

    def to_json(self, metrics: Optional[Sequence[str]] = None,
                indent: Optional[int] = None) -> str:
        return json.dumps({
            "policies": list(self.policies),
            "scenarios": list(self.scenarios),
            "meta": self.meta,
            "rows": self.to_rows(metrics),
        }, indent=indent, sort_keys=True)

    # -- timing -------------------------------------------------------------

    @property
    def wall_s(self) -> float:
        """Total wall-clock over every emitted call."""
        return float(sum(b.wall_s for b in self._blocks))

    def call_walls(self) -> Tuple[float, ...]:
        return tuple(b.wall_s for b in self._blocks)

    def wall_of(self, scenario: str, seed: Optional[int] = None) -> float:
        """Wall of the call that produced ``scenario`` (same-bucket
        scenarios share one call, hence one number)."""
        if seed is None:
            sds = self.seeds(scenario)
            if not sds:
                raise KeyError(f"unknown scenario {scenario!r}")
            seed = sds[0]
        scenario, seed = self._resolve(scenario, seed)
        bi, _ = self._index[(scenario, seed)]
        return self._blocks[bi].wall_s

    def __repr__(self):
        return (f"ResultSet({len(self.scenarios)} scenarios x "
                f"{len(self.policies)} policies, "
                f"metrics={list(self.metrics)[:4]}..., "
                f"wall={self.wall_s:.2f}s)")
