"""Pallas TPU RG-LRU linear-recurrence kernel.

TPU codesign: the recurrence is elementwise over channels, so the natural
tiling is (batch, channel-block) with TIME as the minormost sequential grid
dimension. The hidden state h lives in VMEM scratch across time chunks; a
time chunk of bt steps is unrolled inside the kernel body over VMEM tiles
(bt x bw), which keeps the VPU busy without MXU involvement and streams
a/b exactly once from HBM (the op is memory-bound: 2 loads + 1 store per
element, arithmetic intensity ~1 FLOP/byte).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _rg_lru_kernel(a_ref, b_ref, h0_ref, h_ref, carry, *, bt: int):
    jt = pl.program_id(2)

    @pl.when(jt == 0)
    def _init():
        carry[...] = h0_ref[0].astype(F32)

    h = carry[...]
    a = a_ref[0].astype(F32)          # [bt, bw]
    bb = b_ref[0].astype(F32)
    outs = []
    for t in range(bt):               # unrolled over the VMEM tile
        h = a[t] * h + bb[t]
        outs.append(h)
    h_ref[0] = jnp.stack(outs).astype(h_ref.dtype)
    carry[...] = h


def rg_lru_kernel(a, b, h0, *, bw: int = 128, bt: int = 16,
                  interpret: bool = False):
    """a, b: [B, S, W]; h0: [B, W] -> h [B, S, W]."""
    bsz, s, w = a.shape
    bw = min(bw, w)
    bt = min(bt, s)
    assert w % bw == 0 and s % bt == 0
    kernel = functools.partial(_rg_lru_kernel, bt=bt)
    return pl.pallas_call(
        kernel,
        grid=(bsz, w // bw, s // bt),
        in_specs=[
            pl.BlockSpec((1, bt, bw), lambda i, jw, jt: (i, jt, jw)),
            pl.BlockSpec((1, bt, bw), lambda i, jw, jt: (i, jt, jw)),
            pl.BlockSpec((1, bw), lambda i, jw, jt: (i, jw)),
        ],
        out_specs=pl.BlockSpec((1, bt, bw), lambda i, jw, jt: (i, jt, jw)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), F32)],
        interpret=interpret,
    )(a, b, h0)
