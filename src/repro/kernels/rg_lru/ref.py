"""Oracle for the RG-LRU recurrence kernel: h_t = a_t * h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rg_lru_ref(a, b, h0):
    """a, b: [B, S, W] f32; h0: [B, W]. Returns h: [B, S, W]."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = jnp.moveaxis(a, 1, 0)
    b_t = jnp.moveaxis(b, 1, 0)
    _, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1)
