"""jit'd wrapper for the RG-LRU recurrence kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rg_lru.kernel import rg_lru_kernel


@partial(jax.jit, static_argnames=("bw", "bt", "interpret"))
def rg_lru(a, b, h0, *, bw: int = 128, bt: int = 16,
           interpret: bool = False):
    return rg_lru_kernel(a, b, h0, bw=bw, bt=bt, interpret=interpret)
