"""Pallas one-pass segmented queue recovery for the wavefront engine.

One sequential grid sweep over the wave's N slots (chunks of C) recovers
the L2-bank, DRAM-high-priority and DRAM-low-priority service times
TOGETHER — the three passes the unfused path runs back-to-back collapse
into a single kernel whose cross-chunk state is the combined carry the
segmented-prefix identity needs, per queue:

  * **prefix-occ**  ``S_q``  — total service occupancy of q's requests
    seen so far (the exclusive prefix ``c`` continues across chunks);
  * **running-max** ``M_q``  — ``max_i (max(t_i, floor_i) - c_i)`` so
    far, so ``start_j = c_j + max(M_q, within-chunk running max)``;
  * **predecessor** ``row_q`` / ``HB_q`` — the DRAM row chain's last
    open row per channel and the high-priority queue's busy horizon
    (what the strict-priority low queue floors on).

Within a chunk the same quantities come from ``jnp.cumsum`` /
``lax.associative_scan`` on [C, Q] tiles held in VMEM; chunk reductions
then advance the carry scratch. Occupancies are small integers, so the
re-associated prefix sums are exact (< 2**24) and the kernel matches
ref.py bit-for-bit on dyadic inputs; tests/test_kernels.py pins that
under ``interpret=True`` on fuzzed queue loads.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
I32 = jnp.int32
_NEG = -jnp.inf

_CHUNK = 256


def _scan_max(x):
    return jax.lax.associative_scan(jnp.maximum, x, axis=0)


def _take_q(x_cq, q):
    return jnp.take_along_axis(x_cq, q[:, None], axis=1)[:, 0]


def _queue_kernel(t_s_ref, bank_ref, use_ref, ch_ref, row_ref, go_ref,
                  byp_ref, hp_ref,
                  bank_free_ref, bank_ts_ref, hp_free_ref, hp_ts_ref,
                  hp_sa_ref, lp_free_ref, lp_ts_ref, lp_sa_ref,
                  cur_row_ref,
                  t_head_ref, t0_ref, row_hit_ref,
                  sb_ref, mb_ref, shp_ref, mhp_ref, slp_ref, mlp_ref,
                  hb_ref, lr_ref,
                  *, banks, channels, l2_svc, l2_lat, occ_rowhit,
                  occ_rowmiss, exact):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        sb_ref[0, :] = jnp.zeros((banks,), F32)
        mb_ref[0, :] = jnp.full((banks,), _NEG, F32)
        shp_ref[0, :] = jnp.zeros((channels,), F32)
        mhp_ref[0, :] = jnp.full((channels,), _NEG, F32)
        slp_ref[0, :] = jnp.zeros((channels,), F32)
        mlp_ref[0, :] = jnp.full((channels,), _NEG, F32)
        hb_ref[0, :] = jnp.full((channels,), _NEG, F32)
        lr_ref[0, :] = cur_row_ref[0, :]

    t_s = t_s_ref[0, :]
    bank = bank_ref[0, :]
    ch = ch_ref[0, :]
    row = row_ref[0, :]
    use_l2 = use_ref[0, :] != 0
    go_dram = go_ref[0, :] != 0
    byp = byp_ref[0, :] != 0
    hp = hp_ref[0, :] != 0
    c_len = t_s.shape[0]

    def floor_of(free, last_ts, last_sa, q, t_svc):
        f = free[q]
        if exact:
            return f
        interp = jnp.minimum(f, t_svc + (f - last_sa[q]))
        return jnp.where(t_s >= last_ts[q], f, interp)

    # ---- L2 bank queues (prefix-occ S_b + running-max M_b carry) -----------
    iota_b = jax.lax.broadcasted_iota(I32, (c_len, banks), 1)
    bm = (bank[:, None] == iota_b) & use_l2[:, None]
    occ_b = jnp.where(bm, jnp.float32(l2_svc), 0.0)
    c_loc = jnp.cumsum(occ_b, axis=0) - occ_b
    c_b = sb_ref[0, :][None, :] + c_loc
    u_b = jnp.maximum(t_s, floor_of(bank_free_ref[0, :], bank_ts_ref[0, :],
                                    bank_ts_ref[0, :], bank, t_s))
    v_b = jnp.where(bm, u_b[:, None] - c_b, _NEG)
    m_loc = _scan_max(v_b)
    b_start = c_b + jnp.maximum(mb_ref[0, :][None, :], m_loc)
    t_head = jnp.where(use_l2, _take_q(b_start, bank), 0.0)
    t_head_ref[0, :] = t_head

    # ---- DRAM row-buffer predecessor chain ---------------------------------
    t_da = jnp.where(byp, t_s, t_head + l2_lat)
    iota_c = jax.lax.broadcasted_iota(I32, (c_len, channels), 1)
    slot_c = jax.lax.broadcasted_iota(I32, (c_len, channels), 0)
    cm = (ch[:, None] == iota_c) & go_dram[:, None]
    inc = _scan_max(jnp.where(cm, slot_c, -1))
    prev = jnp.concatenate(
        [jnp.full((1, channels), -1, I32), inc[:-1]], axis=0)
    prev_slot = _take_q(prev, ch)
    prev_row = jnp.where(prev_slot >= 0,
                         jnp.take(row, jnp.maximum(prev_slot, 0)),
                         lr_ref[0, :][ch])
    row_hit = (prev_row == row) & go_dram
    row_hit_ref[0, :] = row_hit.astype(I32)
    occ = jnp.where(row_hit, jnp.float32(occ_rowhit),
                    jnp.float32(occ_rowmiss))

    # ---- high-priority queue ------------------------------------------------
    f_hp = floor_of(hp_free_ref[0, :], hp_ts_ref[0, :], hp_sa_ref[0, :],
                    ch, t_da)
    m_hp = cm & hp[:, None]
    occ_hp = jnp.where(m_hp, occ[:, None], 0.0)
    c_hp = shp_ref[0, :][None, :] + (jnp.cumsum(occ_hp, axis=0) - occ_hp)
    v_hp = jnp.where(m_hp, jnp.maximum(t_da, f_hp)[:, None] - c_hp, _NEG)
    mh_loc = _scan_max(v_hp)
    hp_start = c_hp + jnp.maximum(mhp_ref[0, :][None, :], mh_loc)
    hp_end = jnp.where(m_hp, hp_start + occ_hp, _NEG)
    hp_end_run = _scan_max(hp_end)
    hp_busy = jnp.maximum(
        hb_ref[0, :][None, :],
        jnp.concatenate([jnp.full((1, channels), _NEG),
                         hp_end_run[:-1]], axis=0))

    # ---- low-priority queue (floored on the HP busy horizon) ---------------
    f_lp = floor_of(lp_free_ref[0, :], lp_ts_ref[0, :], lp_sa_ref[0, :],
                    ch, t_da)
    m_lp = cm & ~hp[:, None]
    occ_lp = jnp.where(m_lp, occ[:, None], 0.0)
    c_lp = slp_ref[0, :][None, :] + (jnp.cumsum(occ_lp, axis=0) - occ_lp)
    u_lp = jnp.maximum(t_da, jnp.maximum(
        f_lp, jnp.maximum(f_hp, _take_q(hp_busy, ch))))
    v_lp = jnp.where(m_lp, u_lp[:, None] - c_lp, _NEG)
    ml_loc = _scan_max(v_lp)
    lp_start = c_lp + jnp.maximum(mlp_ref[0, :][None, :], ml_loc)

    t0_ref[0, :] = jnp.where(hp, _take_q(hp_start, ch),
                             _take_q(lp_start, ch))

    # ---- advance the combined carry ----------------------------------------
    last = inc[-1]
    lr_ref[0, :] = jnp.where(last >= 0,
                             jnp.take(row, jnp.maximum(last, 0)),
                             lr_ref[0, :])
    hb_ref[0, :] = jnp.maximum(hb_ref[0, :], hp_end_run[-1])
    sb_ref[0, :] = sb_ref[0, :] + jnp.sum(occ_b, axis=0)
    mb_ref[0, :] = jnp.maximum(mb_ref[0, :], m_loc[-1])
    shp_ref[0, :] = shp_ref[0, :] + jnp.sum(occ_hp, axis=0)
    mhp_ref[0, :] = jnp.maximum(mhp_ref[0, :], mh_loc[-1])
    slp_ref[0, :] = slp_ref[0, :] + jnp.sum(occ_lp, axis=0)
    mlp_ref[0, :] = jnp.maximum(mlp_ref[0, :], ml_loc[-1])


def wave_queue_kernel(t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry,
                      *, banks: int, channels: int, l2_svc: float,
                      l2_lat: float, occ_rowhit: float, occ_rowmiss: float,
                      exact: bool, interpret: bool = False):
    """Chunked one-pass recovery; returns ``(t_head, t0, row_hit)``.

    Same slot-array contract as ``ops.wave_queue_recovery``. The tail
    chunk is padded with all-invalid slots (every mask false), which are
    identity elements for every carried quantity.
    """
    n = t_s.shape[0]
    c_len = min(n, _CHUNK)
    k = -(-n // c_len)
    pad = k * c_len - n

    def shape2(x, fill):
        x = jnp.pad(x, (0, pad), constant_values=fill)
        return x.reshape(k, c_len)

    slot_in = [shape2(t_s, 0.0), shape2(bank, 0),
               shape2(use_l2.astype(I32), 0), shape2(ch, 0),
               shape2(row, 0), shape2(go_dram.astype(I32), 0),
               shape2(byp.astype(I32), 0), shape2(hp.astype(I32), 0)]
    carry_in = [x[None, :] for x in (carry.bank_free, carry.bank_ts,
                                     carry.hp_free, carry.hp_ts,
                                     carry.hp_sa, carry.lp_free,
                                     carry.lp_ts, carry.lp_sa,
                                     carry.cur_row)]

    chunk_spec = pl.BlockSpec((1, c_len), lambda i: (i, 0))
    qf_spec = pl.BlockSpec((1, banks), lambda i: (0, 0))
    qc_spec = pl.BlockSpec((1, channels), lambda i: (0, 0))

    kern = partial(_queue_kernel, banks=banks, channels=channels,
                   l2_svc=l2_svc, l2_lat=l2_lat, occ_rowhit=occ_rowhit,
                   occ_rowmiss=occ_rowmiss, exact=exact)
    t_head, t0, row_hit = pl.pallas_call(
        kern,
        grid=(k,),
        in_specs=[chunk_spec, chunk_spec, chunk_spec, chunk_spec,
                  chunk_spec, chunk_spec, chunk_spec, chunk_spec,
                  qf_spec, qf_spec, qc_spec, qc_spec, qc_spec,
                  qc_spec, qc_spec, qc_spec, qc_spec],
        out_specs=[chunk_spec, chunk_spec, chunk_spec],
        out_shape=[jax.ShapeDtypeStruct((k, c_len), F32),
                   jax.ShapeDtypeStruct((k, c_len), F32),
                   jax.ShapeDtypeStruct((k, c_len), I32)],
        scratch_shapes=[pltpu.VMEM((1, banks), F32),
                        pltpu.VMEM((1, banks), F32),
                        pltpu.VMEM((1, channels), F32),
                        pltpu.VMEM((1, channels), F32),
                        pltpu.VMEM((1, channels), F32),
                        pltpu.VMEM((1, channels), F32),
                        pltpu.VMEM((1, channels), F32),
                        pltpu.VMEM((1, channels), I32)],
        interpret=interpret,
    )(*slot_in, *carry_in)
    return (t_head.reshape(-1)[:n], t0.reshape(-1)[:n],
            row_hit.reshape(-1)[:n] != 0)
