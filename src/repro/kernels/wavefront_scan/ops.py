"""Backend-gated entry point for wavefront segmented queue recovery.

``wave_queue_recovery`` computes one wave's bank / high-priority /
low-priority service times plus the advanced cross-wave queue carry.
Backends:

  * ``"ref"``    — the engine's original unfused multi-pass formulation
    (ref.py): cumsum + ``lax.cummax`` per queue family over [Q, N]
    masks. The unfused side of the in-run perf A/B.
  * ``"fused"``  — bitwise-identical reformulation on slot-major [N, Q]
    layout: the same exclusive-prefix-occupancy / running-max recovery,
    but the pathologically slow XLA:CPU ``cummax`` is replaced by a
    custom ``lax.associative_scan(jnp.maximum)`` (exactly associative,
    so bitwise-equal), the prefix-occupancy cumsums by
    ``associative_scan(jnp.add)`` (exact because service occupancies
    are integer-valued — see ``_scan_add``), per-slot floors are
    gathered instead of materializing [Q, N] floor matrices, and the
    carry update runs as dense masked max reductions sharing one mask
    per queue family (XLA:CPU serializes scatter-max into a
    per-element loop). Every intermediate that reaches an output is
    either the same float operation on the same values as ref.py or an
    exact re-association, so outputs are bit-for-bit equal — which is
    what lets the engine default to it under the 1e-6 golden suites.
  * ``"pallas"`` — one-pass TPU kernel (kernel.py): a single chunked
    sweep with a combined (prefix-occ, running-max, predecessor) carry
    recovers bank, HP and LP service times together. Exact on dyadic
    inputs (integer occupancies; the chunked prefix sums re-associate,
    which is exact below 2**24); validated under ``interpret=True`` on
    CPU, where it is also automatically selected when forced.
  * ``"auto"``   — ``"pallas"`` on TPU, ``"fused"`` elsewhere (the
    pure-lax fallback keeps the SSE2-only CI box on the fast path).

The differential suites pin fused == ref bitwise and pallas == ref on
fuzzed queue loads (tests/test_kernels.py, test_engine_differential.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wavefront_scan import ref as _ref
from repro.kernels.wavefront_scan.kernel import wave_queue_kernel
from repro.kernels.wavefront_scan.ref import QueueCarry

F32 = jnp.float32
I32 = jnp.int32
_NEG = -jnp.inf

BACKENDS = ("auto", "fused", "ref", "pallas")


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown scan backend {backend!r}; choose from {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "fused"
    return backend


def _scan_max(x):
    """Inclusive running max along axis 0. Bitwise-equal to
    ``lax.cummax`` (max is exactly associative and the inputs carry no
    NaNs) but 9–16x faster on XLA:CPU, where the cummax primitive
    lowers to a degenerate reduce-window."""
    return jax.lax.associative_scan(jnp.maximum, x, axis=0)


def _scan_add(x):
    """Inclusive prefix sum along axis 0 via ``associative_scan`` —
    ~4x faster than ``jnp.cumsum`` on XLA:CPU. The tree re-associates
    the additions, which is exact whenever the summands accumulate
    without rounding: queue occupancies are integer-valued service
    times (``l2_svc`` / ``occ_rowhit`` / ``occ_rowmiss``) well below
    2**24, so every partial sum is an exactly-representable integer
    and the fused backend stays bitwise-equal to ref.py's sequential
    ``jnp.cumsum`` on them."""
    return jax.lax.associative_scan(jnp.add, x, axis=0)


def _floor_slot(free, last_ts, last_sa, q, t_s, t_svc, exact):
    """``ref.carry_floor`` evaluated only at each slot's own queue —
    an O(N) gather instead of a [Q, N] matrix. Identical elementwise
    math on identical values, so bitwise-equal where it is consumed."""
    f = free[q]
    if exact:
        return f
    backlog = f - last_sa[q]
    interp = jnp.minimum(f, t_svc + backlog)
    return jnp.where(t_s >= last_ts[q], f, interp)


def _take_q(x_nq, q):
    """x[j, q_j] for per-slot queue gather on [N, Q] arrays."""
    return jnp.take_along_axis(x_nq, q[:, None], axis=1)[:, 0]


def _fused_core(t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry,
                *, banks, channels, l2_svc, l2_lat, occ_rowhit,
                occ_rowmiss, exact):
    """Slot-major [N, Q] recovery; returns (t_head, t0, row_hit)."""
    n = t_s.shape[0]
    slot = jnp.arange(n, dtype=I32)

    # ---- L2 bank queues ----------------------------------------------------
    # the DRAM predecessor-chain scan is independent of the bank scan,
    # so both ride ONE associative scan on a [N, banks+channels] concat
    # (slot indices stay exact in f32 — they are < 2**24)
    bmask = (bank[:, None] == jnp.arange(banks, dtype=I32)[None, :]) \
        & use_l2[:, None]
    cmask = (ch[:, None] == jnp.arange(channels, dtype=I32)[None, :]) \
        & go_dram[:, None]
    occ_b = jnp.where(bmask, jnp.full((n,), l2_svc, F32)[:, None], 0.0)
    c_b = _scan_add(occ_b) - occ_b
    u_b = jnp.maximum(t_s, _floor_slot(carry.bank_free, carry.bank_ts,
                                       carry.bank_ts, bank, t_s, t_s,
                                       exact))
    v_b = jnp.where(bmask, u_b[:, None] - c_b, _NEG)
    chain = jnp.where(cmask, slot[:, None], -1).astype(F32)
    joint = _scan_max(jnp.concatenate([v_b, chain], axis=1))
    b_start = c_b + joint[:, :banks]
    inc = joint[:, banks:].astype(I32)
    t_head = jnp.where(use_l2, _take_q(b_start, bank), 0.0)

    # ---- DRAM two-queue FR-FCFS --------------------------------------------
    t_da = jnp.where(byp, t_s, t_head + l2_lat)
    prev_idx = jnp.concatenate(
        [jnp.full((1, channels), -1, I32), inc[:-1]], axis=0)
    prev_slot = _take_q(prev_idx, ch)
    prev_row = jnp.where(prev_slot >= 0,
                         jnp.take(row, jnp.maximum(prev_slot, 0)),
                         carry.cur_row[ch])
    row_hit = (prev_row == row) & go_dram
    occ = jnp.where(row_hit, occ_rowhit, occ_rowmiss)

    f_hp = _floor_slot(carry.hp_free, carry.hp_ts, carry.hp_sa, ch,
                       t_s, t_da, exact)
    mask_hp = cmask & hp[:, None]
    occ_hp = jnp.where(mask_hp, occ[:, None], 0.0)
    c_hp = _scan_add(occ_hp) - occ_hp
    u_hp = jnp.maximum(t_da, f_hp)
    v_hp = jnp.where(mask_hp, u_hp[:, None] - c_hp, _NEG)
    hp_start = c_hp + _scan_max(v_hp)
    hp_end = jnp.where(mask_hp, hp_start + occ_hp, _NEG)
    hp_busy = jnp.concatenate(
        [jnp.full((1, channels), _NEG), _scan_max(hp_end)[:-1]], axis=0)

    f_lp = _floor_slot(carry.lp_free, carry.lp_ts, carry.lp_sa, ch,
                       t_s, t_da, exact)
    mask_lp = cmask & ~hp[:, None]
    occ_lp = jnp.where(mask_lp, occ[:, None], 0.0)
    c_lp = _scan_add(occ_lp) - occ_lp
    u_lp = jnp.maximum(t_da, jnp.maximum(
        f_lp, jnp.maximum(f_hp, _take_q(hp_busy, ch))))
    v_lp = jnp.where(mask_lp, u_lp[:, None] - c_lp, _NEG)
    lp_start = c_lp + _scan_max(v_lp)

    t0 = jnp.where(hp, _take_q(hp_start, ch), _take_q(lp_start, ch))
    return t_head, t0, row_hit


def _carry_epilogue(t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry,
                    t_head, t0, row_hit, *, banks, channels, l2_svc,
                    l2_lat, occ_rowhit, occ_rowmiss) -> QueueCarry:
    """Advance the cross-wave carry from per-slot outputs.

    Dense masked [N, Q] max reductions, sharing one mask per queue
    family. A scatter-max (`.at[q].max`) would be O(N) on paper but
    lowers to a serialized per-element loop on XLA:CPU — measured ~3x
    slower than the dense reduce at N=4096 — while max is
    order-independent and exact, so both forms are bitwise-equal to
    ref.py's per-queue reductions. The open-row update recovers each
    channel's LAST serviced slot as a masked max over slot indices."""
    n = t_s.shape[0]
    slot = jnp.arange(n, dtype=I32)
    t_da = jnp.where(byp, t_s, t_head + l2_lat)
    occ = jnp.where(row_hit, occ_rowhit, occ_rowmiss)

    bm = (bank[:, None] == jnp.arange(banks, dtype=I32)[None, :]) \
        & use_l2[:, None]
    cm = (ch[:, None] == jnp.arange(channels, dtype=I32)[None, :]) \
        & go_dram[:, None]
    cm_hp = cm & hp[:, None]
    cm_lp = cm & ~hp[:, None]

    def qmax(mask, val, base):
        return jnp.maximum(
            base, jnp.max(jnp.where(mask, val[:, None], _NEG), axis=0))

    last_idx = jnp.max(jnp.where(cm, slot[:, None], -1), axis=0)
    cur_row = jnp.where(last_idx >= 0,
                        jnp.take(row, jnp.maximum(last_idx, 0)),
                        carry.cur_row)
    return QueueCarry(
        bank_free=qmax(bm, t_head + l2_svc, carry.bank_free),
        bank_ts=qmax(bm, t_s, carry.bank_ts),
        hp_free=qmax(cm_hp, t0 + occ, carry.hp_free),
        hp_ts=qmax(cm_hp, t_s, carry.hp_ts),
        hp_sa=qmax(cm_hp, t_da, carry.hp_sa),
        lp_free=qmax(cm_lp, t0 + occ, carry.lp_free),
        lp_ts=qmax(cm_lp, t_s, carry.lp_ts),
        lp_sa=qmax(cm_lp, t_da, carry.lp_sa),
        cur_row=cur_row)


def wave_queue_recovery(t_s, bank, use_l2, ch, row, go_dram, byp, hp,
                        carry: QueueCarry, *, banks: int, channels: int,
                        l2_svc: float, l2_lat: float, occ_rowhit: float,
                        occ_rowmiss: float, exact: bool,
                        backend: str = "auto", interpret: bool = False):
    """One wave's queue recovery under the selected backend.

    Slot arrays are [N] in warp-major chronological order. Returns
    ``(t_head, t0, row_hit, new_carry)`` — see ref.py for the contract.
    ``interpret`` only affects the pallas backend (and is forced on
    automatically when pallas is requested off-TPU, so the kernel path
    stays runnable on the CPU CI box).

    Deliberately NOT jitted here: the wavefront engine inlines it into
    its own jitted wave step (a nested pjit boundary would block XLA
    fusion with the surrounding pass); standalone callers (tests,
    benchmarks/roofline.py) wrap it in ``jax.jit`` at the call site.
    """
    kw = dict(banks=banks, channels=channels, l2_svc=l2_svc,
              l2_lat=l2_lat, occ_rowhit=occ_rowhit,
              occ_rowmiss=occ_rowmiss, exact=exact)
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.wave_queue_recovery_ref(
            t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry, **kw)
    if b == "pallas":
        interp = interpret or jax.default_backend() != "tpu"
        t_head, t0, row_hit = wave_queue_kernel(
            t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry,
            interpret=interp, **kw)
    else:
        t_head, t0, row_hit = _fused_core(
            t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry, **kw)
    new_carry = _carry_epilogue(
        t_s, bank, use_l2, ch, row, go_dram, byp, hp, carry,
        t_head, t0, row_hit, banks=banks, channels=channels,
        l2_svc=l2_svc, l2_lat=l2_lat, occ_rowhit=occ_rowhit,
        occ_rowmiss=occ_rowmiss)
    return t_head, t0, row_hit, new_carry
