"""Unfused multi-pass oracle for wavefront queue recovery.

This is the wavefront engine's original timing-pass formulation,
extracted verbatim (ISSUE 6): one cumsum + ``lax.cummax`` segmented
prefix per queue family over dense ``[Q, N]`` masks, a ``cummax``
predecessor chain for the DRAM row buffer, and a second prefix pass for
the low-priority queue whose floor folds in the high-priority busy
horizon. It recovers, for one wave of N arrival-ordered requests, the
exact FIFO service times the event engine would produce request by
request: ``start_j = c_j + max_{i<=j}(max(t_i, floor_i) - c_i)`` with
``c`` the exclusive prefix occupancy of the request's queue.

It is the differential oracle for ``ops.py``'s fused slot-major
formulation (bitwise-identical, fewer/faster scans) and the Pallas
one-pass kernel (``kernel.py``), and it IS the engine's
``scan_backend="ref"`` path — the unfused side of the in-run A/B that
benchmarks/engine_bench.py gates on.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
I32 = jnp.int32
_NEG = -jnp.inf


class QueueCarry(NamedTuple):
    """Cross-wave queue state threaded through every backend.

    ``*_free`` are busy-until horizons (SimState fields), ``*_ts``/
    ``*_sa`` the service-frontier anchors in wave-sort / service-arrival
    time (wavefront.QueueAnchors), ``cur_row`` the open DRAM row per
    channel."""
    bank_free: jnp.ndarray   # f32[banks]
    bank_ts: jnp.ndarray     # f32[banks]
    hp_free: jnp.ndarray     # f32[channels]
    hp_ts: jnp.ndarray       # f32[channels]
    hp_sa: jnp.ndarray       # f32[channels]
    lp_free: jnp.ndarray     # f32[channels]
    lp_ts: jnp.ndarray       # f32[channels]
    lp_sa: jnp.ndarray       # f32[channels]
    cur_row: jnp.ndarray     # i32[channels]


def carry_floor(free, last_ts, last_sa, t_s, t_svc):
    """Work-conserving carry floor [Q, N] for the next wave's requests.

    A request at/after the queue's serviced frontier (``t_s >= last_ts``)
    waits for the full busy-until, exactly like the event engine. A
    *retrograde* request — its warp raced ahead of the warps that last
    used the queue, so in true event order it would have been serviced
    amid that backlog, not after it — sees the queue's STANDING BACKLOG
    (``free - last_sa``) anchored at its own service-arrival time instead
    of the absolute end-of-service. Single-warp traces are always at the
    frontier, so they stay exact.
    """
    backlog = (free - last_sa)[:, None]              # +inf if queue unused
    interp = jnp.minimum(free[:, None], t_svc[None, :] + backlog)
    return jnp.where(t_s[None, :] >= last_ts[:, None], free[:, None],
                     interp)


def anchor_update(last, mask, t):
    return jnp.maximum(last,
                       jnp.max(jnp.where(mask, t[None, :], _NEG), axis=1))


def queue_prefix(mask, t_arr, occ, free):
    """FIFO service start times for one queue family, vectorized.

    mask: bool[Q, N] — request j belongs to queue q; slots in
    chronological order. t_arr: f32[N] arrivals; occ: f32[N] per-request
    occupancy; free: f32[Q, 1|N] per-slot busy-until floor.

    Returns (start[Q, N], end[Q, N]); ``end`` is -inf outside ``mask`` so
    row-wise maxima skip those entries.
    """
    occ_m = jnp.where(mask, occ[None, :], 0.0)
    c = jnp.cumsum(occ_m, axis=1) - occ_m            # exclusive prefix occ
    v = jnp.where(mask, jnp.maximum(t_arr[None, :], free) - c, _NEG)
    start = c + jax.lax.cummax(v, axis=1)
    end = jnp.where(mask, start + occ_m, _NEG)
    return start, end


def wave_queue_recovery_ref(t_s, bank, use_l2, ch, row, go_dram, byp, hp,
                            carry: QueueCarry, *, banks: int, channels: int,
                            l2_svc: float, l2_lat: float, occ_rowhit: float,
                            occ_rowmiss: float, exact: bool):
    """Recover one wave's bank/HP/LP service times, multi-pass.

    Slot arrays are [N] in warp-major chronological order; ``carry`` is
    the cross-wave queue state. ``exact=True`` (a wave of one warp — the
    event loop) uses the plain busy-until floor instead of the backlog
    interpolation. Returns ``(t_head, t0, row_hit, new_carry)``:
    per-slot L2-bank service start (0 outside ``use_l2``), DRAM service
    start (garbage outside ``go_dram``), row-buffer hit flags, and the
    advanced carry.
    """
    n = t_s.shape[0]
    slot = jnp.arange(n, dtype=I32)

    def floor(free, last_ts, last_sa, t_svc):
        if exact:
            return free[:, None]
        return carry_floor(free, last_ts, last_sa, t_s, t_svc)

    # ---- L2 bank queues ----------------------------------------------------
    bmask = (bank[None, :] == jnp.arange(banks, dtype=I32)[:, None]) \
        & use_l2[None, :]
    svc = jnp.full((n,), l2_svc, F32)
    b_start, b_end = queue_prefix(
        bmask, t_s, svc,
        floor(carry.bank_free, carry.bank_ts, carry.bank_ts, t_s))
    t_head = jnp.sum(jnp.where(bmask, b_start, 0.0), axis=0)
    bank_free = jnp.maximum(carry.bank_free, jnp.max(b_end, axis=1))

    # ---- DRAM two-queue FR-FCFS --------------------------------------------
    t_da = jnp.where(byp, t_s, t_head + l2_lat)
    cmask = (ch[None, :] == jnp.arange(channels, dtype=I32)[:, None]) \
        & go_dram[None, :]

    # row-buffer chain: each request's predecessor is the previous
    # request in its channel within this wave, else the carried open row
    inc = jax.lax.cummax(jnp.where(cmask, slot[None, :], -1), axis=1)
    prev_idx = jnp.concatenate(
        [jnp.full((channels, 1), -1, I32), inc[:, :-1]], axis=1)
    prev_row = jnp.where(prev_idx >= 0,
                         jnp.take(row, jnp.maximum(prev_idx, 0)),
                         carry.cur_row[:, None])
    row_hit = (prev_row == row[None, :])[ch, slot] & go_dram
    occ = jnp.where(row_hit, occ_rowhit, occ_rowmiss)

    mask_hp = cmask & hp[None, :]
    hp_carry = floor(carry.hp_free, carry.hp_ts, carry.hp_sa, t_da)
    hp_start, hp_end = queue_prefix(mask_hp, t_da, occ, hp_carry)
    # strict priority: a low-priority request waits for the high queue's
    # busy horizon at its chronological position
    hp_busy = jnp.concatenate(
        [jnp.full((channels, 1), _NEG),
         jax.lax.cummax(hp_end, axis=1)[:, :-1]], axis=1)
    lp_floor = jnp.maximum(
        floor(carry.lp_free, carry.lp_ts, carry.lp_sa, t_da),
        jnp.maximum(hp_carry, hp_busy))
    mask_lp = cmask & ~hp[None, :]
    lp_start, lp_end = queue_prefix(mask_lp, t_da, occ, lp_floor)

    t0 = jnp.where(hp, hp_start[ch, slot], lp_start[ch, slot])
    hp_free = jnp.maximum(carry.hp_free, jnp.max(hp_end, axis=1))
    lp_free = jnp.maximum(carry.lp_free, jnp.max(lp_end, axis=1))
    last_idx = inc[:, -1]
    cur_row = jnp.where(last_idx >= 0,
                        jnp.take(row, jnp.maximum(last_idx, 0)),
                        carry.cur_row)

    new_carry = QueueCarry(
        bank_free=bank_free,
        bank_ts=anchor_update(carry.bank_ts, bmask, t_s),
        hp_free=hp_free,
        hp_ts=anchor_update(carry.hp_ts, mask_hp, t_s),
        hp_sa=anchor_update(carry.hp_sa, mask_hp, t_da),
        lp_free=lp_free,
        lp_ts=anchor_update(carry.lp_ts, mask_lp, t_s),
        lp_sa=anchor_update(carry.lp_sa, mask_lp, t_da),
        cur_row=cur_row)
    return t_head, t0, row_hit, new_carry
