"""Pallas TPU block-pool gather: pool pages -> contiguous per-sequence KV.

This is the *bypass/stream* path of the MeDiC pool manager: blocks of a
mostly-miss sequence are streamed through a transient contiguous buffer
(never pinned in the pool), and re-fetched host blocks are landed the same
way. The whole kernel is BlockSpec-driven: the index map chases the block
table from scalar-prefetch SMEM, so each grid step is exactly one
HBM->HBM(VMEM-staged) page DMA; holes (< 0) write zeros without issuing a
fetch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(tbl_ref, pool_ref, out_ref):
    b = pl.program_id(0)
    j = pl.program_id(1)
    resident = tbl_ref[b, j] >= 0

    @pl.when(resident)
    def _copy():
        out_ref[0, 0] = pool_ref[0]

    @pl.when(~resident)
    def _zero():
        out_ref[0, 0] = jnp.zeros_like(out_ref[0, 0])


def medic_gather_kernel(pool, block_tbl, *, interpret: bool = False):
    """pool: [N, page, H, D]; block_tbl: [B, P] -> [B, P, page, H, D]."""
    n, page, h, d = pool.shape
    b, p = block_tbl.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, p),
        in_specs=[
            pl.BlockSpec((1, page, h, d),
                         lambda b_, j, tbl: (jnp.maximum(tbl[b_, j], 0),
                                             0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, page, h, d),
                               lambda b_, j, tbl: (b_, j, 0, 0, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, p, page, h, d), pool.dtype),
        interpret=interpret,
    )(block_tbl, pool)
