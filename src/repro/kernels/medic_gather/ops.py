"""jit'd wrapper for the MeDiC block-pool gather."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.medic_gather.kernel import medic_gather_kernel


@partial(jax.jit, static_argnames=("interpret",))
def medic_gather(pool, block_tbl, *, interpret: bool = False):
    return medic_gather_kernel(pool, block_tbl, interpret=interpret)
