"""Oracle for the MeDiC block-pool gather."""
from __future__ import annotations

import jax.numpy as jnp


def medic_gather_ref(pool, block_tbl):
    """pool: [N, page, H, D]; block_tbl: [B, P] (<0 = hole -> zeros).
    Returns [B, P, page, H, D]."""
    tbl = jnp.maximum(block_tbl, 0)
    out = pool[tbl]
    mask = (block_tbl >= 0)[..., None, None, None]
    return jnp.where(mask, out, jnp.zeros_like(out))
