"""Pallas TPU paged decode-attention kernel.

The altitude-B hot loop: one decode token per sequence reads its KV blocks
*directly out of the shared block pool* via the block table — the gather IS
the cache lookup, so a pool "hit" never materializes a contiguous KV copy.

TPU codesign notes:
  * block table + sequence lengths ride in scalar-prefetch SMEM
    (PrefetchScalarGridSpec) so BlockSpec index maps can chase the table:
    the kv tile for grid step (b, h, j) is pool[tbl[b, j]] — a
    data-dependent HBM->VMEM DMA, which is exactly the TPU analogue of the
    paper's "request steered by the cache tag lookup";
  * the page axis is the minormost (sequential) grid dimension; online-
    softmax stats live in VMEM scratch across pages;
  * non-resident pages (tbl < 0, the MeDiC bypass/evicted case) are skipped
    with pl.when — no DMA is issued for them on hardware.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _decode_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, page, npages):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    resident = tbl_ref[b, j] >= 0

    @pl.when(resident)
    def _compute():
        q = q_ref[0, 0].astype(F32)                   # [G, D]
        k = k_ref[0, :, 0, :].astype(F32)             # [page, D]
        v = v_ref[0, :, 0, :].astype(F32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=F32) * scale       # [G, page]
        pos = j * page + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        mask = pos < len_ref[b]
        logits = jnp.where(mask, logits, NEG_INF)
        s_max = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_scr[...], s_max)
        p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_scr[...] - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=F32))
        m_scr[...] = m_new

    @pl.when(j == npages - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def paged_decode_attention_kernel(q, k_pool, v_pool, block_tbl, lengths, *,
                                  interpret: bool = False):
    """q: [B, Hkv, G, D]; pools: [N, page, Hkv, D]; block_tbl: [B, P]."""
    b, hkv, g, d = q.shape
    n, page, _, _ = k_pool.shape
    p = block_tbl.shape[1]
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_decode_kernel, scale=scale, page=page,
                               npages=p)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, p),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j, tbl, ln: (b_, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, j, tbl, ln: (
                             jnp.maximum(tbl[b_, j], 0), 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, j, tbl, ln: (
                             jnp.maximum(tbl[b_, j], 0), 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h, j, tbl, ln: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), F32),
            pltpu.VMEM((g,), F32),
            pltpu.VMEM((g, d), F32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(block_tbl, lengths, q, k_pool, v_pool)
