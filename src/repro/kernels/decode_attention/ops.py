"""jit'd wrapper for paged decode attention."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.kernel import paged_decode_attention_kernel


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tbl, lengths, *,
                           interpret: bool = False):
    """q: [B, Hkv, G, D] one-token queries; pools [N, page, Hkv, D];
    block_tbl [B, P] (entries < 0 = non-resident, masked); lengths [B]."""
    return paged_decode_attention_kernel(
        q, k_pool, v_pool, block_tbl, lengths, interpret=interpret)
