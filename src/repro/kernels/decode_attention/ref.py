"""Pure-jnp oracle for paged decode attention.

One new token per sequence attends over a paged KV pool through a block
table. Entries < 0 in the block table are holes (not resident); the oracle
treats them as fully masked (the runtime fetches them through the MeDiC
host-tier path before calling the kernel).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pool, v_pool, block_tbl, lengths):
    """q: [B, Hkv, G, D]; pools: [N, page, Hkv, D]; block_tbl: [B, P];
    lengths: [B]. Returns [B, Hkv, G, D]."""
    b, hkv, g, d = q.shape
    n, page, _, _ = k_pool.shape
    p = block_tbl.shape[1]
    scale = 1.0 / math.sqrt(d)

    tbl = jnp.maximum(block_tbl, 0)
    k = k_pool[tbl]                                   # [B, P, page, Hkv, D]
    v = v_pool[tbl]
    k = jnp.moveaxis(k, 3, 1).reshape(b, hkv, p * page, d)
    v = jnp.moveaxis(v, 3, 1).reshape(b, hkv, p * page, d)
    pos = jnp.arange(p * page)[None]
    resident = jnp.repeat(block_tbl >= 0, page, axis=1)
    valid = (pos < lengths[:, None]) & resident       # [B, P*page]

    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(F32), k.astype(F32))
    logits = logits * scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid[:, None, None, :], w, 0.0)
    o = jnp.einsum("bhgs,bhsd->bhgd", w, v.astype(F32))
    return o.astype(q.dtype)
