"""jit'd public wrapper for the flash attention kernel.

Accepts the model layout q [B, S, H, D], k/v [B, Skv, Hkv, D] and handles
the [BH, S, D] kernel layout, GQA head folding and the interpret flag
(interpret=True executes the kernel body in Python on CPU for validation;
on TPU pass interpret=False).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128, interpret: bool = False):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    # [B,S,H,D] -> [B*H, S, D] with q heads grouped so q-head index // g
    # recovers the kv head: order heads as (kv_head, group)
    qt = jnp.transpose(q.reshape(b, s, hkv, g, d), (0, 2, 3, 1, 4))
    qt = qt.reshape(b * hkv * g, s, d)
    kt = jnp.transpose(k, (0, 2, 1, 3)).reshape(b * hkv, k.shape[1], d)
    vt = jnp.transpose(v, (0, 2, 1, 3)).reshape(b * hkv, v.shape[1], d)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             bq=bq, bk=bk, interpret=interpret)
    o = o.reshape(b, hkv, g, s, d)
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(b, s, h, d)
