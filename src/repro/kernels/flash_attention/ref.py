"""Pure-jnp oracle for the flash attention kernel.

Layout convention for the kernel family: q [B, S, H, D], k/v [B, S, Hkv, D]
with GQA group G = H // Hkv. Computation in f32, output cast to q.dtype.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax

F32 = jnp.float32
NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, s, hkv, g, d).astype(F32)
    logits = jnp.einsum("bqkgd,bskd->bqkgs", qg, k.astype(F32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bqkgs,bskd->bqkgd", w, v.astype(F32))
    return o.reshape(b, s, h, d).astype(q.dtype)
