"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA).

TPU-codesigned tiling:
  * grid = (B * Hkv * G, nq, nk) — the kv axis is the minormost grid
    dimension, which TPU executes sequentially per core, so the online-
    softmax running statistics live in VMEM scratch across kv steps;
  * q/o blocks (bq, d) and k/v blocks (bk, d) are VMEM-resident tiles;
    bq/bk default to 128/256 to keep the (bq x bk) logits tile MXU-aligned
    (multiples of 128) and the working set
    (bq*d + 2*bk*d + bq*bk) * 4B well under the ~16 MB VMEM budget;
  * GQA is expressed through the k/v BlockSpec index maps (q-head
    bh -> kv-head bh // G) so kv tiles are fetched once per group, not
    duplicated in HBM;
  * causal/SWA tiles that cannot intersect the mask are skipped with
    pl.when (on hardware the fetch is also elided since the block is not
    written), giving near-triangular work.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  nk: int, seq_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = kj * bk

    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window is not None:
        live = live & (k_start + bk - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(F32)                       # [bq, d]
        k = k_ref[0].astype(F32)                       # [bk, d]
        v = v_ref[0].astype(F32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=F32) * scale        # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_kv
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]                            # [bq]
        s_max = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_prev, s_max)
        p = jnp.exp(logits - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window=None,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = False):
    """q: [BHq, S, D]; k/v: [BHkv, Skv, D]. BHq = BHkv * G."""
    bh, s, d = q.shape
    bhkv, sk, _ = k.shape
    g = bh // bhkv
    bq = min(bq, s)
    bk = min(bk, sk)
    assert s % bq == 0 and sk % bk == 0
    nq, nk = s // bq, sk // bk
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, seq_kv=sk)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            _scratch((bq,), F32),
            _scratch((bq,), F32),
            _scratch((bq, d), F32),
        ],
        interpret=interpret,
    )(q, k, v)


def _scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
