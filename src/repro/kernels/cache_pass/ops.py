"""Backend-gated entry point for the wavefront cache pass.

``wave_cache_pass`` services one wave's B×L requests — bypass decision,
L2 tag lookup, RRIP fill/eviction, EAF + PC-table bookkeeping, and the
classifier observe — and returns the advanced state plus the per-lane
record tuple the timing pass consumes. Backends:

  * ``"ref"``    — the original per-lane ``lax.scan`` (ref.py), carried
    over verbatim from the engine. The unfused side of the in-run perf
    A/B and the parity oracle.
  * ``"fused"``  — bitwise-identical one-sweep reformulation. Duplicate
    set indices between a lane's wave members (lanes CAN alias sets
    even though warp ids are distinct) resolve last-write-wins in slot
    order — the ordering the sequential ref scan gets for free. The
    sweep picks one of two constructions per wave width (a static,
    shape-level choice — B is fixed per compiled wave step):

      - wide waves (B ≥ 128, where same-set aliasing is dense and the
        scatter volume dominates): the CHRONOLOGY-POINTER construction.
        Every slot's post-write row lands in a private row buffer (one
        dynamic-update-slice per lane), and conflict resolution is an
        explicit segmented argmax over chronological slot index per
        touched set: each writing slot scatter-MAXES its chronology
        index into a per-set pointer table, so after the sweep each
        set's pointer names exactly the last slot in program order that
        wrote it; the winning rows are dereferenced once at the end.
        Three pointer chains ride one fused [2·sets + eaf_bits] table:
        tags+meta advance only on ``allocate``, RRIP on every
        ``use_l2`` request (hits rewrite their row), and the EAF write
        degenerates to the same scatter-max because the generation
        stamp is monotone nondecreasing. The construction is bitwise
        because (a) every slot's row is computed from lane-start state
        — exactly what the ref scatters write — and (b) same-lane
        same-set allocators share identical lane-start RRIP rows, hence
        the same victim way, so the winning row subsumes the losers'
        single-element writes.
      - narrow waves (B < 128, paper scale, where the pass is dispatch-
        bound — every extra XLA fusion boundary costs more than the
        work it saves): the ref-shaped masked scatters are kept (XLA
        applies scatter updates in operand order, which IS slot order,
        so the same last-write-wins semantics fall out and the aliasing
        suites pin them), and the win comes from retiring redundant
        dispatches: the three PC counters travel as ONE stacked
        [pc_entries, 3] working table (one gather + one row scatter-add
        per lane instead of three of each), the hit-way ``argmax`` is
        dropped (the tag-match mask already IS the hit-way one-hot: a
        line lives in at most one way of its set), and the per-request
        index/draw precomputation is folded into the lane body where
        XLA fuses it for free, keeping the lane scan's sliced inputs
        down to the address matrix alone.

    Wide waves also sort the wave by PC entry once (slots sharing a PC
    entry form segments) so each lane's counter reads are exact segment
    sums off one cumsum and the [pc_entries] tables take a single
    conflict-free scatter-add at wave end. This is the CPU default.
  * ``"pallas"`` — lane-chunked TPU kernel (kernel.py): grid over the
    L lanes with the cache state carried in VMEM scratch and all
    gather/scatter replaced by dense one-hot selects/reductions.
    Validated under ``interpret=True`` off-TPU (no TPU-hardware run yet
    — the caveat ROADMAP carries for wavefront_scan applies here too).
  * ``"auto"``   — ``"pallas"`` on TPU, ``"fused"`` elsewhere.

The differential suites pin fused == ref == pallas bitwise on every
metric across the workload × policy matrix and on adversarial same-set
aliasing grids (tests/test_kernels.py, tests/test_engine_differential.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import classifier as CLF
from repro.core.engine import request as REQ
from repro.core.engine.state import SimParams, SimState
from repro.kernels.cache_pass import ref as _ref
from repro.kernels.cache_pass.kernel import wave_cache_kernel
from repro.policy import PolicyArrays, ops as POL

F32 = jnp.float32
I32 = jnp.int32

BACKENDS = ("auto", "fused", "ref", "pallas")

# Static wave-width threshold between the two fused constructions. Below
# it the pass is dispatch-bound and ref-shaped scatters are effectively
# free; above it scatter volume dominates and the chronology-pointer
# merge + sorted-PC segments pay for their fixed overhead.
WIDE_WAVE_MIN_B = 128


def resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown cache backend {backend!r}; choose from {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "fused"
    return backend


def _fused_narrow(st: SimState, clf_b0: CLF.ClassifierState, tokens_b,
                  t0, addr_lb, pc_b, owt_b, slot_ok,
                  prm: SimParams, pa: PolicyArrays) -> tuple:
    """Narrow-wave (B < 128) fused sweep — see the module docstring.

    The lane body mirrors ``ref.lane_cache_step`` line for line; the
    deltas are all dispatch-count reductions: one stacked PC table, no
    hit-way argmax, per-request indices/draws computed in-body (fused),
    and a lane scan whose sliced inputs are just (lane, addr row).
    """
    lanes, B = addr_lb.shape
    W = prm.ways
    obs_consts = _ref.observe_consts(prm, pa)
    pidx = REQ.pc_index(pc_b, prm)                    # constant across lanes
    pc_tab0 = jnp.stack([st.pc_hits, st.pc_acc, st.pc_req], axis=1)

    def lane_step(carry, x):
        tags, meta, rrip, eaf, eaf_gen, eaf_ctr, clf_b, pc_tab = carry
        lane, addr = x
        # pure-in-addr precomputation: elementwise, fused into the body
        valid = (addr >= 0) & slot_ok
        sidx = REQ.set_index(addr, prm)
        erd = REQ.eaf_index(addr, prm)
        rand_u = REQ.hash_index(addr, 7, 65536).astype(F32) / 65536.0
        t_arr = t0 + lane.astype(F32) * prm.lane_skew

        # ---- ①② label select + bypass decision ----------------------------
        pc_vals = pc_tab[pidx]                        # [B, 3] one gather
        byp, wtype = REQ.bypass_decision_core(
            clf_b.warp_type, clf_b.accesses, tokens_b, pc_vals[:, 0],
            pc_vals[:, 1], pc_vals[:, 2], addr, valid, prm, pa, owt_b,
            rand_u=rand_u)
        use_l2 = valid & ~byp

        # ---- L2 lookup (lane-start rows) -----------------------------------
        tset = tags[sidx]
        # the match mask doubles as the hit-way one-hot: a line lives in
        # at most one way of its set (allocation happens only on miss;
        # same-lane duplicate allocators pick the same victim)
        is_line = tset == addr[:, None]
        hit = jnp.any(is_line, axis=1) & use_l2
        rset = rrip[sidx]
        rset = jnp.where(hit[:, None] & is_line, 0, rset)

        # ---- ③ fill + insertion --------------------------------------------
        allocate = use_l2 & ~hit
        shift = prm.rrip_max - jnp.max(rset, axis=1)
        rset_aged = rset + jnp.where(allocate, shift, 0)[:, None]
        victim = jnp.argmax(rset_aged, axis=1)
        evicted = jnp.take_along_axis(tset, victim[:, None], axis=1)[:, 0]
        victim_type = meta[sidx, victim]
        ebit = eaf[erd] == eaf_gen
        rank = POL.insertion_rank(pa, wtype=wtype, eaf_bit=ebit,
                                  rrip_max=prm.rrip_max)

        # ---- slot-ordered masked scatters (LWW falls out of the
        # ---- operand-order application; pinned by the aliasing suites) -----
        s_alloc = jnp.where(allocate, sidx, prm.sets)
        tags = tags.at[s_alloc, victim].set(addr, mode="drop")
        vict_oh = jnp.arange(W, dtype=I32)[None, :] == victim[:, None]
        new_row = jnp.where(allocate[:, None],
                            jnp.where(vict_oh, rank[:, None], rset_aged),
                            rset)
        s_l2 = jnp.where(use_l2, sidx, prm.sets)
        rrip = rrip.at[s_l2].set(new_row, mode="drop")
        meta = meta.at[s_alloc, victim].set(wtype, mode="drop")
        ev_valid = allocate & (evicted >= 0)
        eidx = REQ.eaf_index(evicted, prm)
        eaf = eaf.at[jnp.where(ev_valid, eidx, prm.eaf_bits)].set(
            eaf_gen, mode="drop")

        # ---- ① classifier + PC table + EAF counter -------------------------
        clf_b = _ref.observe_vec(clf_b, hit, valid.astype(I32),
                                 use_l2.astype(I32), prm, pa,
                                 consts=obs_consts)
        delta = jnp.stack([(hit & use_l2), use_l2, valid, ev_valid],
                          axis=1).astype(I32)
        pc_tab = pc_tab.at[pidx].add(delta[:, :3])    # one row scatter-add
        n_ev = jnp.sum(ev_valid.astype(I32))
        eaf_ctr = eaf_ctr + n_ev
        reset = eaf_ctr >= prm.eaf_capacity
        eaf_gen = jnp.where(reset, eaf_gen + 1, eaf_gen)
        eaf_ctr = jnp.where(reset, 0, eaf_ctr)

        hp = POL.is_high_priority(pa, wtype)
        rec = (t_arr, addr, valid, byp, use_l2, hit, hp,
               victim_type, ev_valid)
        return (tags, meta, rrip, eaf, eaf_gen, eaf_ctr, clf_b, pc_tab), rec

    carry0 = (st.tags, st.meta_type, st.rrip, st.eaf, st.eaf_gen,
              st.eaf_ctr, clf_b0, pc_tab0)
    xs = (jnp.arange(lanes, dtype=I32), addr_lb)
    carry, records = jax.lax.scan(lane_step, carry0, xs)
    tags, meta, rrip, eaf, eaf_gen, eaf_ctr, clf_b, pc_tab = carry
    new_st = st._replace(
        tags=tags, rrip=rrip, meta_type=meta, eaf=eaf, eaf_gen=eaf_gen,
        eaf_ctr=eaf_ctr, pc_hits=pc_tab[:, 0], pc_acc=pc_tab[:, 1],
        pc_req=pc_tab[:, 2])
    return new_st, clf_b, records


def _fused_wide(st: SimState, clf_b0: CLF.ClassifierState, tokens_b,
                t0, addr_lb, pc_b, owt_b, slot_ok,
                prm: SimParams, pa: PolicyArrays) -> tuple:
    """Wide-wave (B ≥ 128) fused sweep — the chronology-pointer
    construction (see the module docstring). Per lane: one fused
    3B-index gather resolves tag/meta, RRIP, and EAF reads through the
    pointer table; every slot's post-write row lands in a private row
    buffer via one dynamic-update-slice; and the explicit last-write-
    wins reduction is a single 3B-element scatter-MAX of chronology
    indices (segmented argmax over slot order per touched set), with
    non-writing slots parked one-past-the-end and dropped.
    """
    lanes, B = addr_lb.shape
    W = prm.ways
    S = prm.sets
    E = prm.pc_entries
    DROP = 2 * S + prm.eaf_bits                       # park index, dropped
    slot = jnp.arange(B, dtype=I32)
    obs_consts = _ref.observe_consts(prm, pa)
    pidx = REQ.pc_index(pc_b, prm)                    # constant across lanes

    # ---- PC segments: sort the wave by PC entry once -----------------------
    # slots sharing an entry form runs; per lane, one cumsum over the
    # sorted deltas + two gathers yield each slot's exact running entry
    # total (integer adds commute), so counter reads are scatter-free
    # and the [E] tables take ONE conflict-free scatter-add at wave end.
    pperm = jnp.argsort(pidx)                         # stable
    spidx = pidx[pperm]
    inv = jnp.argsort(pperm)
    brk = spidx[1:] != spidx[:-1]
    is_end = jnp.concatenate([brk, jnp.ones((1,), bool)])
    seg_start = jax.lax.cummax(
        jnp.where(jnp.concatenate([jnp.ones((1,), bool), brk]), slot, -1))
    seg_end = jax.lax.cummin(jnp.where(is_end, slot, B), reverse=True)
    first_seg = seg_start == 0
    seg_idx = jnp.concatenate([seg_end, jnp.maximum(seg_start - 1, 0)])
    base_pc = jnp.stack([st.pc_hits[pidx], st.pc_acc[pidx],
                         st.pc_req[pidx]], axis=1)    # [B, 3]

    # ---- row buffer + chronology-pointer table -----------------------------
    # buf rows 0..S-1 hold the wave-start [tags | meta | rrip] rows; each
    # lane's B slots own rows S + lane·B + slot. A set's current row is
    # buf[pointer]; pointers only ever move FORWARD in chronology, which
    # is what makes the scatter-max below an exact LWW reduction.
    buf0 = jnp.concatenate(
        [jnp.concatenate([st.tags, st.meta_type, st.rrip], axis=1),
         jnp.zeros((lanes * B, 3 * W), I32)], axis=0)
    # one fused table: [tag/meta ptrs | rrip ptrs | EAF stamps]. The EAF
    # chain shares the max-reduction because the generation stamp is
    # monotone nondecreasing (stored stamps ≤ current gen).
    mtab0 = jnp.concatenate(
        [jnp.tile(jnp.arange(S, dtype=I32), 2), st.eaf])

    def lane_step(carry, x):
        buf, mtab, eaf_gen, eaf_ctr, clf_b, acc_b = carry
        lane, addr = x
        valid = (addr >= 0) & slot_ok
        sidx = REQ.set_index(addr, prm)
        erd = REQ.eaf_index(addr, prm)
        rand_u = REQ.hash_index(addr, 7, 65536).astype(F32) / 65536.0
        t_arr = t0 + lane.astype(F32) * prm.lane_skew

        # ---- ①② label select + bypass decision ----------------------------
        pc_vals = base_pc + acc_b
        byp, wtype = REQ.bypass_decision_core(
            clf_b.warp_type, clf_b.accesses, tokens_b, pc_vals[:, 0],
            pc_vals[:, 1], pc_vals[:, 2], addr, valid, prm, pa, owt_b,
            rand_u=rand_u)
        use_l2 = valid & ~byp

        # ---- L2 lookup: one pointer gather, one row gather -----------------
        rd = mtab[jnp.concatenate([sidx, S + sidx, 2 * S + erd])]
        rows2 = buf[rd[:2 * B]]                       # [2B, 3W]
        tset, mrow = rows2[:B, :W], rows2[:B, W:2 * W]
        is_line = tset == addr[:, None]
        hit = jnp.any(is_line, axis=1) & use_l2
        rset = rows2[B:, 2 * W:]
        rset = jnp.where(hit[:, None] & is_line, 0, rset)

        # ---- ③ fill + insertion --------------------------------------------
        allocate = use_l2 & ~hit
        shift = prm.rrip_max - jnp.max(rset, axis=1)
        rset_aged = rset + jnp.where(allocate, shift, 0)[:, None]
        victim = jnp.argmax(rset_aged, axis=1)
        vict_oh = jnp.arange(W, dtype=I32)[None, :] == victim[:, None]
        pair = jnp.take_along_axis(                   # evicted tag + its type
            rows2[:B, :2 * W],
            jnp.stack([victim, W + victim], axis=1), axis=1)
        evicted, victim_type = pair[:, 0], pair[:, 1]
        ebit = rd[2 * B:] == eaf_gen
        rank = POL.insertion_rank(pa, wtype=wtype, eaf_bit=ebit,
                                  rrip_max=prm.rrip_max)

        # ---- private row buffer + explicit LWW pointer reduction -----------
        new_row = jnp.concatenate(
            [jnp.where(vict_oh, addr[:, None], tset),
             jnp.where(vict_oh, wtype[:, None], mrow),
             jnp.where(allocate[:, None],
                       jnp.where(vict_oh, rank[:, None], rset_aged),
                       rset)], axis=1)
        base = S + lane * B
        buf = jax.lax.dynamic_update_slice(buf, new_row, (base, 0))
        ev_valid = allocate & (evicted >= 0)
        chrono = base + slot                          # strictly slot-ordered
        wr_at = jnp.concatenate(
            [jnp.where(allocate, sidx, DROP),
             jnp.where(use_l2, S + sidx, DROP),
             jnp.where(ev_valid, 2 * S + REQ.eaf_index(evicted, prm),
                       DROP)])
        wr_val = jnp.concatenate(
            [chrono, chrono, jnp.broadcast_to(eaf_gen, (B,))])
        mtab = mtab.at[wr_at].max(wr_val, mode="drop")

        # ---- ① classifier + PC segments + EAF counter ----------------------
        clf_b = _ref.observe_vec(clf_b, hit, valid.astype(I32),
                                 use_l2.astype(I32), prm, pa,
                                 consts=obs_consts)
        delta = jnp.stack([(hit & use_l2), use_l2, valid, ev_valid],
                          axis=1).astype(I32)
        csum = jnp.cumsum(delta[pperm], axis=0)
        g = csum[seg_idx]                             # [2B, 4] seg ends/starts
        tot = g[:B] - jnp.where(first_seg[:, None], 0, g[B:])
        acc_b = acc_b + tot[inv, :3]
        n_ev = csum[B - 1, 3]
        eaf_ctr = eaf_ctr + n_ev
        reset = eaf_ctr >= prm.eaf_capacity
        eaf_gen = jnp.where(reset, eaf_gen + 1, eaf_gen)
        eaf_ctr = jnp.where(reset, 0, eaf_ctr)

        hp = POL.is_high_priority(pa, wtype)
        rec = (t_arr, addr, valid, byp, use_l2, hit, hp,
               victim_type, ev_valid)
        return (buf, mtab, eaf_gen, eaf_ctr, clf_b, acc_b), rec

    carry0 = (buf0, mtab0, st.eaf_gen, st.eaf_ctr, clf_b0,
              jnp.zeros((B, 3), I32))
    xs = (jnp.arange(lanes, dtype=I32), addr_lb)
    carry, records = jax.lax.scan(lane_step, carry0, xs)
    buf, mtab, eaf_gen, eaf_ctr, clf_b, acc_b = carry

    # dereference the winning rows once; write each PC entry's total at
    # its segment end (conflict-free by construction)
    fin = buf[mtab[:2 * S]]
    pc_fin = jnp.stack([st.pc_hits, st.pc_acc, st.pc_req], axis=1).at[
        jnp.where(is_end, spidx, E)].add(acc_b[pperm], mode="drop")
    new_st = st._replace(
        tags=fin[:S, :W], rrip=fin[S:, 2 * W:], meta_type=fin[:S, W:2 * W],
        eaf=mtab[2 * S:], eaf_gen=eaf_gen, eaf_ctr=eaf_ctr,
        pc_hits=pc_fin[:, 0], pc_acc=pc_fin[:, 1], pc_req=pc_fin[:, 2])
    return new_st, clf_b, records


def _fused_sweep(st: SimState, clf_b0: CLF.ClassifierState, tokens_b,
                 t0, addr_lb, pc_b, owt_b, slot_ok,
                 prm: SimParams, pa: PolicyArrays) -> tuple:
    """One-sweep cache pass; picks the construction by wave width (a
    static shape property — see the module docstring)."""
    _, B = addr_lb.shape
    impl = _fused_wide if B >= WIDE_WAVE_MIN_B else _fused_narrow
    return impl(st, clf_b0, tokens_b, t0, addr_lb, pc_b, owt_b, slot_ok,
                prm, pa)


def wave_cache_pass(st: SimState, clf_b0: CLF.ClassifierState, tokens_b,
                    t0, addr_lb, pc_b, owt_b, slot_ok, prm: SimParams,
                    pa: PolicyArrays, *, backend: str = "auto",
                    interpret: bool = False) -> tuple:
    """One wave's cache pass under the selected backend.

    Deliberately NOT jitted here: the engine inlines it into its own
    jitted wave step (jitting at this level would force the [sets, ways]
    state through a call boundary every wave). ``interpret`` forces the
    Pallas kernel's interpreter mode; off-TPU it is implied.
    """
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.wave_cache_pass_ref(st, clf_b0, tokens_b, t0, addr_lb,
                                        pc_b, owt_b, slot_ok, prm, pa)
    if b == "pallas":
        return wave_cache_kernel(st, clf_b0, tokens_b, t0, addr_lb, pc_b,
                                 owt_b, slot_ok, prm, pa,
                                 interpret=interpret
                                 or jax.default_backend() != "tpu")
    return _fused_sweep(st, clf_b0, tokens_b, t0, addr_lb, pc_b, owt_b,
                        slot_ok, prm, pa)
