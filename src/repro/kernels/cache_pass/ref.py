"""Reference wavefront cache pass: the per-lane sequential scan.

Extracted verbatim from ``engine/wavefront.py`` (where it lived through
PR 7) so it can serve as the unfused side of the in-run perf A/B and as
the parity oracle for the fused/Pallas backends in this package. One
wave of B warps runs L lane sub-steps under ``jax.lax.scan``; each lane
services at most ONE request per warp, [B]-vectorized, slots in
chronological order:

  * ②  bypass decision from the carried classifier rows + PC table,
  * L2 tag lookup against the sub-step-start tags,
  * ③  RRIP fill/aging/eviction with masked scatters (an out-of-bounds
    set index drops the update; duplicate-set conflicts between wave
    members resolve last-write-wins in slot order — the semantics the
    fused backend must reproduce explicitly),
  * EAF and PC-table bookkeeping,
  * ①  the classifier observe on wave-resident [B] counter slices
    (``observe_vec``; gathered once per wave, scattered back once by the
    engine — sound because wave warp ids are distinct).

None of these outcomes depend on request *timing*, so the pass needs no
queue state; the per-lane record tuple feeds the timing pass and the
per-wave hoisted metrics (lifetime counters and scalar sums are integer
adds that nothing reads mid-wave, so the engine applies them once per
wave for every backend).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import classifier as CLF
from repro.core import warp_types as WT
from repro.core.engine import request as REQ
from repro.core.engine.state import SimParams, SimState
from repro.policy import PolicyArrays, ops as POL

F32 = jnp.float32
I32 = jnp.int32


def observe_consts(prm: SimParams, pa: PolicyArrays) -> tuple:
    """The policy-only observe scalars ``(interval, max_windows,
    min_samples)`` — pure in ``(prm, pa)``, so the fused sweep computes
    them once per wave and passes them to every lane's ``observe_vec``
    instead of re-deriving them L times."""
    interval = POL.reclass_interval(pa, prm.sampling_interval)
    max_windows = POL.reclass_max_windows(pa)
    min_samples = CLF.min_probe_samples(
        interval, POL.probe_interval(pa, prm.probe_interval))
    return interval, max_windows, min_samples


def observe_gathered(clf: CLF.ClassifierState, w, is_hit, weight, probed,
                     prm: SimParams, pa: PolicyArrays
                     ) -> CLF.ClassifierState:
    """``classifier.observe`` restricted to the B touched warps.

    Equivalent to the full-width observe — an untouched warp's counters
    don't change, so its window can never reset on this call — but costs
    O(B) gather/scatter instead of O(W) elementwise work per sub-step.
    Wave warp ids are distinct, so the scatters don't collide. Parity
    with `CLF.observe` is pinned by tests/test_engine_differential.py.
    Kept as the documented bridge between ``CLF.observe`` and the
    wave-resident ``observe_vec`` below (which is this function minus
    the gather/scatter, on rows the engine keeps wave-resident).

    The sampling window, probe cadence and label-freeze cap come from
    the policy (①, same knobs the event engine passes to
    ``CLF.observe``); ``probed`` marks the cache-path requests whose
    undiluted sample the window ratio is measured over.
    """
    interval, max_windows, min_samples = observe_consts(prm, pa)
    hits = clf.hits[w] + is_hit.astype(I32) * probed
    accesses = clf.accesses[w] + weight
    sampled = clf.sampled[w] + probed
    due = accesses >= interval
    ratio_now = hits.astype(jnp.float32) / jnp.maximum(sampled, 1)
    new_type = WT.classify(ratio_now, sampled,
                           mostly_hit_threshold=prm.mostly_hit_threshold,
                           mostly_miss_threshold=prm.mostly_miss_threshold,
                           min_samples=min_samples)
    relabel = due & (clf.windows[w] < max_windows)
    return CLF.ClassifierState(
        hits=clf.hits.at[w].set(jnp.where(due, 0, hits)),
        accesses=clf.accesses.at[w].set(jnp.where(due, 0, accesses)),
        warp_type=clf.warp_type.at[w].set(
            jnp.where(relabel, new_type, clf.warp_type[w])),
        ratio=clf.ratio.at[w].set(jnp.where(due, ratio_now, clf.ratio[w])),
        windows=clf.windows.at[w].add(due.astype(I32)),
        sampled=clf.sampled.at[w].set(jnp.where(due, 0, sampled)),
    )


def observe_vec(clf_b: CLF.ClassifierState, is_hit, weight, probed,
                prm: SimParams, pa: PolicyArrays,
                consts: Optional[tuple] = None) -> CLF.ClassifierState:
    """``observe_gathered`` on wave-resident [B] counter slices.

    The engine gathers the wave's classifier rows ONCE before the cache
    pass, every backend updates them as plain [B] vectors here (no
    per-lane gather/scatter against the [W] arrays — XLA:CPU serializes
    those), and the engine scatters them back once per wave. Sound
    because wave warp ids are distinct: nothing else reads or writes
    those rows mid-wave, so the carried slice is exactly what a fresh
    gather would return, and the write-back stores exactly what the
    per-lane scatters would have."""
    interval, max_windows, min_samples = (
        observe_consts(prm, pa) if consts is None else consts)
    hits = clf_b.hits + is_hit.astype(I32) * probed
    accesses = clf_b.accesses + weight
    sampled = clf_b.sampled + probed
    due = accesses >= interval
    ratio_now = hits.astype(jnp.float32) / jnp.maximum(sampled, 1)
    new_type = WT.classify(ratio_now, sampled,
                           mostly_hit_threshold=prm.mostly_hit_threshold,
                           mostly_miss_threshold=prm.mostly_miss_threshold,
                           min_samples=min_samples)
    relabel = due & (clf_b.windows < max_windows)
    return CLF.ClassifierState(
        hits=jnp.where(due, 0, hits),
        accesses=jnp.where(due, 0, accesses),
        warp_type=jnp.where(relabel, new_type, clf_b.warp_type),
        ratio=jnp.where(due, ratio_now, clf_b.ratio),
        windows=clf_b.windows + due.astype(I32),
        sampled=jnp.where(due, 0, sampled))


def lane_cache_step(st: SimState, t_arr, addr, pc, valid, owt,
                    prm: SimParams, pa: PolicyArrays,
                    clf_b: CLF.ClassifierState, tokens_b) -> tuple:
    """One lane sub-step of a wave: the timing-independent half of
    ``event._request_step`` for [B] requests (at most one per warp),
    slots in chronological order. Returns ``(st, clf_b, record)``.

    ``clf_b`` carries the wave's classifier rows as [B] vectors through
    the lane scan instead of gathering/scattering the [W] arrays every
    lane — see ``observe_vec`` for why that is bitwise-equivalent.
    Lifetime counters and scalar metric sums — write-only until
    finalize — are hoisted to one per-wave update in the engine; the
    per-lane outputs it needs ride along in the record tuple.
    """
    # ---- ①② label select + bypass decision (shared branchless math) --------
    byp, wtype, pidx = REQ.bypass_decision_vals(
        clf_b.warp_type, clf_b.accesses, tokens_b, st, addr, pc,
        valid, prm, pa, owt)
    use_l2 = valid & ~byp

    # ---- L2 lookup (sub-step-start tags) -----------------------------------
    sidx = REQ.set_index(addr, prm)
    tset = st.tags[sidx]                              # [B, ways]
    is_line = tset == addr[:, None]
    hit = jnp.any(is_line, axis=1) & use_l2
    hit_way = jnp.argmax(is_line, axis=1)
    way_oh = jnp.arange(prm.ways, dtype=I32)[None, :] == hit_way[:, None]
    rset = st.rrip[sidx]
    rset = jnp.where(hit[:, None] & way_oh, 0, rset)

    # ---- ③ fill + insertion -------------------------------------------------
    allocate = use_l2 & ~hit
    shift = prm.rrip_max - jnp.max(rset, axis=1)
    rset_aged = rset + jnp.where(allocate, shift, 0)[:, None]
    victim = jnp.argmax(rset_aged, axis=1)
    evicted = jnp.take_along_axis(tset, victim[:, None], axis=1)[:, 0]
    victim_type = st.meta_type[sidx, victim]          # read BEFORE overwrite
    rank = REQ.insertion_rank(st, wtype, addr, prm, pa)

    # masked scatters: an out-of-bounds set index drops the update, and
    # duplicate-set conflicts resolve last-write-wins in arrival order
    s_alloc = jnp.where(allocate, sidx, prm.sets)
    tags = st.tags.at[s_alloc, victim].set(addr, mode="drop")
    vict_oh = jnp.arange(prm.ways, dtype=I32)[None, :] == victim[:, None]
    new_row = jnp.where(allocate[:, None],
                        jnp.where(vict_oh, rank[:, None], rset_aged), rset)
    s_l2 = jnp.where(use_l2, sidx, prm.sets)
    rrip = st.rrip.at[s_l2].set(new_row, mode="drop")
    meta_type = st.meta_type.at[s_alloc, victim].set(wtype, mode="drop")

    # EAF bookkeeping: remember evicted addresses; the periodic reset is
    # a generation bump (state.py), not an array clear
    ev_valid = allocate & (evicted >= 0)
    eidx = REQ.eaf_index(evicted, prm)
    eaf = st.eaf.at[jnp.where(ev_valid, eidx, prm.eaf_bits)].set(
        st.eaf_gen, mode="drop")
    eaf_ctr = st.eaf_ctr + jnp.sum(ev_valid.astype(I32))
    reset = eaf_ctr >= prm.eaf_capacity
    eaf_gen = jnp.where(reset, st.eaf_gen + 1, st.eaf_gen)
    eaf_ctr = jnp.where(reset, 0, eaf_ctr)

    # ---- ① classifier + PC table (read by later lanes — never hoisted) -----
    clf_b = observe_vec(clf_b, hit, valid.astype(I32),
                        use_l2.astype(I32), prm, pa)
    pc_hits = st.pc_hits.at[pidx].add((hit & use_l2).astype(I32))
    pc_acc = st.pc_acc.at[pidx].add(use_l2.astype(I32))
    pc_req = st.pc_req.at[pidx].add(valid.astype(I32))

    new_st = st._replace(
        tags=tags, rrip=rrip, meta_type=meta_type, eaf=eaf,
        eaf_gen=eaf_gen, eaf_ctr=eaf_ctr, pc_hits=pc_hits, pc_acc=pc_acc,
        pc_req=pc_req)

    hp = POL.is_high_priority(pa, wtype)
    return new_st, clf_b, (t_arr, addr, valid, byp, use_l2, hit, hp,
                           victim_type, ev_valid)


def wave_cache_pass_ref(st: SimState, clf_b0: CLF.ClassifierState,
                        tokens_b, t0, addr_lb, pc_b, owt_b, slot_ok,
                        prm: SimParams, pa: PolicyArrays) -> tuple:
    """One wave's full cache pass: the L-lane ``lax.scan`` driver.

    ``addr_lb`` is i32[L, B] (lane-major: the engine's swapaxes of the
    wave's [B, L] line block); ``t0``/``pc_b``/``owt_b``/``slot_ok``/
    ``tokens_b`` are per-slot [B]. Returns ``(st, clf_b, records)`` with
    each record stacked [L, B] in lane-major chronological order —
    exactly the layout the timing pass flattens warp-major.
    """
    lanes = addr_lb.shape[0]
    xs = (jnp.arange(lanes, dtype=I32), addr_lb)

    def lane_step(c, x):
        s, cb = c
        lane, addr = x                               # i32[], i32[B]
        valid = (addr >= 0) & slot_ok
        t_arr = t0 + lane.astype(F32) * prm.lane_skew
        s, cb, rec = lane_cache_step(s, t_arr, addr, pc_b, valid, owt_b,
                                     prm, pa, cb, tokens_b)
        return (s, cb), rec

    (st, clf_b), recs = jax.lax.scan(lane_step, (st, clf_b0), xs)
    return st, clf_b, recs
