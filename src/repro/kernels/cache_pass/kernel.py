"""Pallas lane-chunked cache pass for the wavefront engine.

One sequential grid sweep over the wave's L lanes, with the whole cache
state — tags/RRIP/meta rows, EAF bits + generation, PC-table counters
and the wave's classifier rows — resident in VMEM scratch between grid
steps (the [sets, ways] arrays are ~16KB each at paper scale, far under
the VMEM budget). Each grid step services one lane's [B] requests with
the exact per-lane math of ``ref.lane_cache_step`` applied to the
scratch-held state, writes the lane's record block, and the final step
flushes the advanced state to the outputs. Because a grid step consumes
the state exactly as the reference scan's lane sub-step does, parity
with the ref/fused backends is structural — pinned bitwise by
tests/test_kernels.py under ``interpret=True``.

Caveat (shared with ``wavefront_scan``, tracked in ROADMAP): only
interpreter mode is exercised in CI — no TPU-hardware run yet, and the
in-kernel gathers (tag-row reads by set index) would need one-hot
reformulation for a Mosaic lowering pass to be attempted.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import classifier as CLF
from repro.core.engine.state import SimParams, SimState
from repro.kernels.cache_pass import ref as _ref
from repro.policy import PolicyArrays

F32 = jnp.float32
I32 = jnp.int32

#: SimState fields the cache pass reads/writes (scratch-carried, in
#: order); the queue/metric fields are dead inside the pass and enter
#: the kernel as zeros.
_STATE_FIELDS = ("tags", "rrip", "meta_type", "eaf", "eaf_gen", "eaf_ctr",
                 "pc_hits", "pc_acc", "pc_req")
_N_STATE = len(_STATE_FIELDS)
_N_CLF = len(CLF.ClassifierState._fields)
_N_REC = 9


def _cache_kernel(*refs, lanes, n_pa, pa_treedef, pa_shapes, prm):
    """Grid step = one lane. ``refs`` layout (inputs, outputs, scratch):

    inputs:  addr [1, B] (lane-blocked) · t0, pc_b, owt_b, slot_ok,
             tokens_b [B] · clf rows ×6 · state ×9 · pa leaves ×n_pa
    outputs: state ×9 · clf rows ×6 · records ×9 ([1, B] lane-blocked)
    scratch: state ×9 · clf rows ×6 (VMEM)
    """
    n_in = 6 + _N_CLF + _N_STATE + n_pa
    n_out = _N_STATE + _N_CLF + _N_REC
    ins, outs, scratch = (refs[:n_in], refs[n_in:n_in + n_out],
                          refs[n_in + n_out:])
    (addr_ref, t0_ref, pc_ref, owt_ref, ok_ref, tok_ref) = ins[:6]
    clf_in = ins[6:6 + _N_CLF]
    st_in = ins[6 + _N_CLF:6 + _N_CLF + _N_STATE]
    pa_in = ins[6 + _N_CLF + _N_STATE:]
    st_out = outs[:_N_STATE]
    clf_out = outs[_N_STATE:_N_STATE + _N_CLF]
    rec_out = outs[_N_STATE + _N_CLF:]
    st_sc = scratch[:_N_STATE]
    clf_sc = scratch[_N_STATE:]

    k = pl.program_id(0)

    @pl.when(k == 0)
    def _load():
        for dst, src in zip(st_sc + clf_sc, st_in + clf_in):
            dst[...] = src[...]

    pa = jax.tree_util.tree_unflatten(
        pa_treedef,
        [r[...].reshape(s) for r, s in zip(pa_in, pa_shapes)])
    sv = dict(zip(_STATE_FIELDS, (r[...] for r in st_sc)))
    zb = jnp.zeros((1,), F32)
    zi = jnp.zeros((1,), I32)
    st = SimState(tags=sv["tags"], rrip=sv["rrip"],
                  meta_type=sv["meta_type"], bank_free=zb, cur_row=zi,
                  hp_free=zb, lp_free=zb, clf=None,
                  eaf=sv["eaf"], eaf_gen=sv["eaf_gen"][0],
                  eaf_ctr=sv["eaf_ctr"][0], pc_hits=sv["pc_hits"],
                  pc_acc=sv["pc_acc"], pc_req=sv["pc_req"],
                  tot_hits=zi, tot_acc=zi, metrics={})
    clf_b = CLF.ClassifierState(*(r[...] for r in clf_sc))

    addr = addr_ref[0, :]
    slot_ok = ok_ref[...]
    valid = (addr >= 0) & slot_ok
    t_arr = t0_ref[...] + k.astype(F32) * prm.lane_skew
    st, clf_b, rec = _ref.lane_cache_step(
        st, t_arr, addr, pc_ref[...], valid, owt_ref[...], prm, pa,
        clf_b, tok_ref[...])

    for dst, name in zip(st_sc, _STATE_FIELDS):
        v = getattr(st, name)
        dst[...] = v.reshape(dst.shape) if v.ndim == 0 else v
    for dst, v in zip(clf_sc, clf_b):
        dst[...] = v
    for dst, v in zip(rec_out, rec):
        dst[0, :] = v

    @pl.when(k == lanes - 1)
    def _flush():
        for dst, src in zip(st_out + clf_out, st_sc + clf_sc):
            dst[...] = src[...]


def wave_cache_kernel(st: SimState, clf_b0: CLF.ClassifierState, tokens_b,
                      t0, addr_lb, pc_b, owt_b, slot_ok, prm: SimParams,
                      pa: PolicyArrays, *, interpret: bool = False
                      ) -> tuple:
    """``ops.wave_cache_pass`` backend ``"pallas"``: same signature and
    return contract as ``ref.wave_cache_pass_ref``."""
    lanes, B = addr_lb.shape
    pa_leaves, pa_treedef = jax.tree_util.tree_flatten(pa)
    pa_shapes = tuple(x.shape for x in pa_leaves)
    st_vals = [jnp.atleast_1d(getattr(st, f)) for f in _STATE_FIELDS]

    whole = lambda x: pl.BlockSpec(x.shape, lambda i: (0,) * x.ndim)
    lane_spec = pl.BlockSpec((1, B), lambda i: (i, 0))

    in_arrays = ([addr_lb, t0, pc_b, owt_b, slot_ok, tokens_b]
                 + list(clf_b0) + st_vals
                 + [jnp.atleast_1d(x) for x in pa_leaves])
    in_specs = [lane_spec] + [whole(x) for x in in_arrays[1:]]

    out_shape = ([jax.ShapeDtypeStruct(x.shape, x.dtype) for x in st_vals]
                 + [jax.ShapeDtypeStruct(x.shape, x.dtype) for x in clf_b0]
                 + [jax.ShapeDtypeStruct((lanes, B), d)
                    for d in (F32, I32, bool, bool, bool, bool, bool,
                              I32, bool)])
    out_specs = ([whole(x) for x in st_vals] + [whole(x) for x in clf_b0]
                 + [lane_spec] * _N_REC)

    scratch = ([pltpu.VMEM(x.shape, x.dtype) for x in st_vals]
               + [pltpu.VMEM(x.shape, x.dtype) for x in clf_b0])

    outs = pl.pallas_call(
        partial(_cache_kernel, lanes=lanes, n_pa=len(pa_leaves),
                pa_treedef=pa_treedef, pa_shapes=pa_shapes, prm=prm),
        grid=(lanes,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*in_arrays)

    st_new = outs[:_N_STATE]
    clf_new = outs[_N_STATE:_N_STATE + _N_CLF]
    recs = tuple(outs[_N_STATE + _N_CLF:])
    upd = {f: (v.reshape(getattr(st, f).shape)
               if getattr(st, f).ndim == 0 else v)
           for f, v in zip(_STATE_FIELDS, st_new)}
    return (st._replace(**upd), CLF.ClassifierState(*clf_new), recs)
