"""jit'd wrapper for the chunkwise mLSTM kernel (model layout adapter)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mlstm.kernel import mlstm_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm(q, k, v, li, lf, *, chunk: int = 64, interpret: bool = False):
    """Model layout: q,k [B,S,H,Dk]; v [B,S,H,Dv]; li/lf [B,S,H]."""
    b, s, h, dk = q.shape
    dv = v.shape[3]

    def fold(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * h, s, *x.shape[3:])

    out = mlstm_kernel(fold(q), fold(k), fold(v),
                       fold(li[..., None])[..., 0],
                       fold(lf[..., None])[..., 0],
                       chunk=chunk, interpret=interpret)
    return jnp.moveaxis(out.reshape(b, h, s, dv), 1, 2)
