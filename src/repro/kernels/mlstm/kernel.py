"""Pallas TPU chunkwise mLSTM kernel.

TPU codesign: the matrix memory C [Dk, Dv] plus normalizer/stabilizer live
in VMEM scratch per (batch, head); the grid walks chunks of the sequence as
the minormost (sequential) dimension. Within a chunk the math is dense
matmuls on (chunk x Dk)/(chunk x chunk) tiles — MXU-shaped — while the
cross-chunk recurrence runs in exact stabilized form (identical numerics to
the chunkwise reference in repro.models.xlstm).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, h_ref,
                  c_scr, n_scr, m_scr, *, chunk: int, scale: float):
    jc = pl.program_id(1)

    @pl.when(jc == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)

    q = q_ref[0].astype(F32)          # [C, Dk]
    k = k_ref[0].astype(F32)
    v = v_ref[0].astype(F32)          # [C, Dv]
    li = li_ref[0, :, 0]              # [C] (padded lane dim)
    lf = lf_ref[0, :, 0]

    bcum = jnp.cumsum(lf)             # [C]
    btot = bcum[-1]
    m_prev = m_scr[0, 0]

    dmat = bcum[:, None] - bcum[None, :] + li[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dmat = jnp.where(tri, dmat, NEG_INF)
    g = bcum + m_prev
    m_loc = jnp.maximum(jnp.max(dmat, axis=1), g)

    w = jnp.exp(dmat - m_loc[:, None])
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32) * scale
    wqk = w * qk
    inter = jnp.exp(g - m_loc)
    num = (jax.lax.dot_general(wqk, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=F32)
           + inter[:, None] * jax.lax.dot_general(
               q, c_scr[...], (((1,), (0,)), ((), ())),
               preferred_element_type=F32) * scale)
    den_dot = (jnp.sum(wqk, axis=1)
               + inter * (q @ n_scr[:, 0]) * scale)
    den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_loc))
    h_ref[0] = (num / den[:, None]).astype(h_ref.dtype)

    # state to chunk end
    dend = btot - bcum + li
    m_new = jnp.maximum(btot + m_prev, jnp.max(dend))
    sc = jnp.exp(dend - m_new)
    c_scr[...] = (jnp.exp(btot + m_prev - m_new) * c_scr[...]
                  + jax.lax.dot_general(k * sc[:, None], v,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=F32))
    n_scr[...] = (jnp.exp(btot + m_prev - m_new) * n_scr[...]
                  + (k * sc[:, None]).sum(axis=0)[:, None])
    m_scr[...] = jnp.full_like(m_scr, m_new)


def mlstm_kernel(q, k, v, li, lf, *, chunk: int = 64,
                 interpret: bool = False):
    """q,k: [BH, S, Dk]; v: [BH, S, Dv]; li/lf: [BH, S]. -> h [BH, S, Dv]."""
    bh, s, dk = q.shape
    dv = v.shape[2]
    chunk = min(chunk, s)
    assert s % chunk == 0
    scale = 1.0 / math.sqrt(dk)
    li2 = li[..., None]  # pad a lane dim for TPU-friendly 2D+ blocks
    lf2 = lf[..., None]
    kernel = functools.partial(_mlstm_kernel, chunk=chunk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), F32),
            pltpu.VMEM((dk, 1), F32),
            pltpu.VMEM((1, 1), F32),
        ],
        interpret=interpret,
    )(q, k, v, li2, lf2)
