"""Oracle for the chunkwise mLSTM kernel: the exact recurrent form."""
from __future__ import annotations

from repro.models.xlstm import mlstm_recurrent_ref


def mlstm_ref(q, k, v, li, lf):
    """q,k: [B,S,H,Dk]; v: [B,S,H,Dv]; li/lf: [B,S,H] (i preact, logsig f).
    Returns h: [B,S,H,Dv]."""
    h, _ = mlstm_recurrent_ref(q, k, v, li, lf, None)
    return h
