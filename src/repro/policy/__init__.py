"""Unified branchless policy engine (see DESIGN.md §3).

One array-backed implementation of the paper's four MeDiC decision points —
① classifier (via ``repro.core.classifier``), ② bypass, ③ insertion,
④ two-queue scheduling priority — shared by the altitude-A simulator and
the altitude-B serving pool:

  * ``Policy``        — declarative preset (strings, for humans/presets);
  * ``PolicyArrays``  — the same policy as a pytree of one-hot select
    weights and scalar knobs, suitable for tracing and ``jax.vmap``;
  * ``ops``           — pure, branchless decision functions driven by a
    ``PolicyArrays`` (every mechanism's candidate decision is computed and
    a one-hot dot-product selects the active one — no Python dispatch);
  * ``DecisionTables`` — per-warp-type numpy lookup tables *derived from
    the same ops*, for host-side control planes (the serving pool).

Because a ``PolicyArrays`` is a traced argument (not a static one), every
policy shares a single jit trace, and stacking policies along a leading
axis turns a full policy sweep into one vmapped call
(``core.simulator.simulate_sweep``).
"""
from repro.policy.spec import (BYPASS_MECHS, INSERT_MECHS, LABEL_MECHS,
                               Policy, PolicyArrays, stack_policies,
                               to_arrays)
from repro.policy.tables import DecisionTables
from repro.policy import ops

__all__ = [
    "BYPASS_MECHS", "INSERT_MECHS", "LABEL_MECHS", "Policy",
    "PolicyArrays", "stack_policies", "to_arrays", "DecisionTables", "ops",
]
