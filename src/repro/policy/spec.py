"""Policy description: declarative preset + array-backed pytree form.

``Policy`` keeps the human-readable preset (strings name the mechanism at
each decision point). ``PolicyArrays`` is the form the compute paths use:
one-hot select weights over the mechanism menus plus scalar knobs. It is a
NamedTuple of jnp scalars/vectors, i.e. a pytree — it can be passed as a
*traced* jit argument (one trace for all policies) and stacked along a
leading axis for a vmapped policy sweep.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

F32 = jnp.float32

# mechanism menus — index order is the select-weight order everywhere
BYPASS_MECHS = ("none", "medic", "pcal", "pcbyp", "rand")   # ②
INSERT_MECHS = ("lru", "medic", "eaf")                      # ③
SCHED_MECHS = ("frfcfs", "medic")                           # ④
LABEL_MECHS = ("online", "stale", "oracle")                 # ① labeling


@dataclasses.dataclass(frozen=True)
class Policy:
    """Which mechanism drives each decision point (declarative preset)."""
    name: str
    bypass: str = "none"       # none | medic | pcal | pcbyp | rand
    insertion: str = "lru"     # lru | medic | eaf
    scheduler: str = "frfcfs"  # frfcfs | medic
    rand_p: float = 0.5        # rand bypass probability
    pcal_frac: float = 0.375   # fraction of warps holding tokens
    # ① how warp-type labels track drift (ISSUE 5):
    #   online — periodic reclassification every sampling window (paper);
    #   stale  — classify each warp once, then freeze (phase-0 labels);
    #   oracle — ground-truth per-phase labels from the trace generator.
    labeling: str = "online"
    # sampling window in accesses; 0 = the SimParams default. A
    # policy-visible knob so one vmapped sweep can compare windows.
    reclass_interval: int = 0
    # probe cadence in accesses: every ``probe_interval``-th access of a
    # bypassing warp still takes the cache path so the classifier keeps
    # an undiluted cache-path sample (the probe stream) to re-learn
    # from. 0 = the SimParams default (8). Traced and sweepable
    # alongside ``reclass_interval``.
    probe_interval: int = 0

    def __post_init__(self):
        if self.bypass not in BYPASS_MECHS:
            raise ValueError(f"unknown bypass mechanism {self.bypass!r}")
        if self.insertion not in INSERT_MECHS:
            raise ValueError(f"unknown insertion mechanism {self.insertion!r}")
        if self.scheduler not in SCHED_MECHS:
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        if self.labeling not in LABEL_MECHS:
            raise ValueError(f"unknown labeling mechanism {self.labeling!r}")
        if self.reclass_interval < 0 or \
                self.reclass_interval != int(self.reclass_interval):
            raise ValueError(
                f"reclass_interval must be a non-negative int, got "
                f"{self.reclass_interval!r}")
        if self.probe_interval < 0 or \
                self.probe_interval != int(self.probe_interval):
            raise ValueError(
                f"probe_interval must be a non-negative int, got "
                f"{self.probe_interval!r}")


class PolicyArrays(NamedTuple):
    """A ``Policy`` as arrays. All leaves are jnp; a leading batch axis
    (added by ``stack_policies``) makes this a stacked policy batch."""
    bypass_sel: jnp.ndarray    # f32[5] one-hot over BYPASS_MECHS
    ins_sel: jnp.ndarray       # f32[3] one-hot over INSERT_MECHS
    sched_medic: jnp.ndarray   # f32[]  1.0 iff scheduler == "medic"
    rand_p: jnp.ndarray        # f32[]
    pcal_frac: jnp.ndarray     # f32[]
    label_sel: jnp.ndarray     # f32[3] one-hot over LABEL_MECHS
    reclass_interval: jnp.ndarray  # f32[] 0 = SimParams default
    probe_interval: jnp.ndarray    # f32[] 0 = SimParams default


def _one_hot(index: int, n: int) -> jnp.ndarray:
    return jnp.zeros((n,), F32).at[index].set(1.0)


def to_arrays(pol: Policy) -> PolicyArrays:
    return PolicyArrays(
        bypass_sel=_one_hot(BYPASS_MECHS.index(pol.bypass),
                            len(BYPASS_MECHS)),
        ins_sel=_one_hot(INSERT_MECHS.index(pol.insertion),
                         len(INSERT_MECHS)),
        sched_medic=jnp.asarray(1.0 if pol.scheduler == "medic" else 0.0,
                                F32),
        rand_p=jnp.asarray(pol.rand_p, F32),
        pcal_frac=jnp.asarray(pol.pcal_frac, F32),
        label_sel=_one_hot(LABEL_MECHS.index(pol.labeling),
                           len(LABEL_MECHS)),
        reclass_interval=jnp.asarray(pol.reclass_interval, F32),
        probe_interval=jnp.asarray(pol.probe_interval, F32),
    )


def stack_policies(policies: Sequence[Policy]) -> PolicyArrays:
    """Stack presets into one batched ``PolicyArrays`` (leading axis P)."""
    if not policies:
        raise ValueError("stack_policies needs at least one policy")
    return jax.tree.map(lambda *xs: jnp.stack(xs),
                        *[to_arrays(p) for p in policies])
