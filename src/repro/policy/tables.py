"""Host-side decision tables derived from the branchless policy ops.

The serving pool's control plane runs on the host (numpy) but must make
the *same* ②③④ decisions as the jitted simulator. Since each of those
decisions, for a control plane without PC tables or PCAL tokens, is a pure
function of the warp/sequence type, we evaluate the ops once over all
``NUM_TYPES`` types and cache the result as numpy lookup tables — the ops
remain the single source of truth for mechanism semantics.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import warp_types as WT
from repro.policy import ops
from repro.policy.spec import PolicyArrays

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class DecisionTables:
    """Per-warp-type decisions for one policy, as numpy arrays."""
    bypass_by_type: np.ndarray   # bool[NUM_TYPES]  ②
    rank_by_type: np.ndarray     # i64[NUM_TYPES]   ③
    hp_by_type: np.ndarray       # bool[NUM_TYPES]  ④

    @staticmethod
    def from_arrays(pa: PolicyArrays, rrip_max: int) -> "DecisionTables":
        types = jnp.arange(WT.NUM_TYPES, dtype=I32)
        # signals a host control plane does not have are neutralized:
        # no probe, token held (PCAL never bypasses), empty PC table,
        # rand_u = 1 (rand never fires).
        byp = ops.bypass_decision(
            pa, wtype=types,
            probe=jnp.zeros((WT.NUM_TYPES,), bool),
            token_bit=jnp.ones((WT.NUM_TYPES,), bool),
            pc_hits=jnp.zeros((WT.NUM_TYPES,), I32),
            pc_acc=jnp.zeros((WT.NUM_TYPES,), I32),
            pc_req=jnp.zeros((WT.NUM_TYPES,), I32),
            rand_u=jnp.ones((WT.NUM_TYPES,), F32))
        rank = ops.insertion_rank(
            pa, wtype=types, eaf_bit=jnp.zeros((WT.NUM_TYPES,), bool),
            rrip_max=rrip_max)
        hp = ops.is_high_priority(pa, types)
        return DecisionTables(
            bypass_by_type=np.asarray(byp, bool),
            rank_by_type=np.asarray(rank, np.int64),
            hp_by_type=np.asarray(hp, bool))
