"""Branchless policy decision ops (paper mechanisms ②③④).

Every function computes the candidate decision of *each* mechanism on the
menu and selects the active one with a one-hot dot product against the
``PolicyArrays`` select weights — no Python dispatch, so a single jit
trace covers every policy and a stacked ``PolicyArrays`` vmaps cleanly.

Callers supply the raw signals a hardware decision point would see
(current warp type, PC-table counters, PCAL token bit, a per-address
uniform variate); the ops own the mechanism semantics.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import warp_types as WT
from repro.policy.spec import PolicyArrays

F32 = jnp.float32
I32 = jnp.int32

#: default classifier probe cadence (accesses): every Nth access of a
#: bypassing warp still takes the cache path, keeping an undiluted
#: cache-path sample stream alive for reclassification. Deferred to when
#: ``PolicyArrays.probe_interval`` is 0 (via ``SimParams.probe_interval``).
DEFAULT_PROBE_INTERVAL = 8

#: PC-table probe cadence (requests): every Nth *request* hitting a PC
#: entry takes the cache path even if the entry's ratio says bypass, so
#: the entry's hit/access counters — which only advance on the cache
#: path — keep sampling and a reformed PC can recover. The cadence
#: counter is ``SimState.pc_req`` (all valid requests), NOT ``pc_acc``:
#: gating the probe on a counter that freezes while bypassing would fire
#: at most once more after bypassing starts, then never again.
PC_PROBE_INTERVAL = 16


def hash_index(x, salt, mod):
    """Knuth-style multiplicative hash -> [0, mod). Shared by the
    simulator's set/bank/channel indexing and the policy ops."""
    h = (jnp.asarray(x).astype(jnp.uint32) * jnp.uint32(2654435761)
         + jnp.uint32(salt) * jnp.uint32(0x9E3779B9))
    h ^= h >> 15
    return (h % jnp.uint32(mod)).astype(I32)


def bypass_decision(pa: PolicyArrays, *, wtype, probe, token_bit,
                    pc_hits, pc_acc, pc_req, rand_u):
    """② Should this request skip the shared cache?

    wtype:     i32[] current warp/sequence type (mechanism "medic")
    probe:     bool[] periodic re-learning probe (forces the cache path)
    token_bit: bool[] PCAL token ownership (mechanism "pcal")
    pc_hits/pc_acc: i32[] PC-table cache-path counters (mechanism "pcbyp")
    pc_req:    i32[] PC-table all-request cadence counter (probe clock)
    rand_u:    f32[] uniform variate in [0,1) (mechanism "rand")
    """
    c_none = jnp.zeros(jnp.shape(wtype), bool)
    c_medic = WT.is_bypass_type(wtype) & ~probe
    c_pcal = ~token_bit
    pc_ratio = pc_hits / jnp.maximum(pc_acc, 1)
    # probe on the Nth request of each cadence window (not the zeroth —
    # `% N == 0` would fire on a fresh entry's very first request)
    pc_probe = (pc_req % PC_PROBE_INTERVAL) == PC_PROBE_INTERVAL - 1
    c_pcbyp = (pc_acc > 32) & (pc_ratio < 0.25) & ~pc_probe
    c_rand = rand_u < pa.rand_p
    cand = jnp.stack([c_none, c_medic, c_pcal, c_pcbyp, c_rand]).astype(F32)
    return jnp.tensordot(pa.bypass_sel, cand, axes=1) > 0.5


def insertion_rank(pa: PolicyArrays, *, wtype, eaf_bit, rrip_max: int):
    """③ RRIP insertion rank for a filled line/block.

    eaf_bit: bool[] — the address was seen in the evicted-address filter.
    """
    r_lru = jnp.zeros(jnp.shape(wtype), I32)
    r_medic = WT.insertion_rank(wtype, rrip_max - 1)
    r_eaf = jnp.where(eaf_bit, 0, rrip_max - 1).astype(I32)
    cand = jnp.stack([r_lru, r_medic, r_eaf]).astype(F32)
    return jnp.round(jnp.tensordot(pa.ins_sel, cand, axes=1)).astype(I32)


def is_high_priority(pa: PolicyArrays, wtype):
    """④ Does this request take the strict-priority high queue?"""
    return (pa.sched_medic > 0.5) & WT.is_priority_type(wtype)


def select_label(pa: PolicyArrays, clf_wtype, oracle_wtype):
    """① Which warp-type label drives decisions ②③④ for this request.

    ``label_sel`` is one-hot over LABEL_MECHS = (online, stale, oracle);
    online and stale both READ the classifier's label (stale differs in
    how the label is *updated* — see ``reclass_max_windows``), oracle
    substitutes the trace generator's ground-truth per-phase label.
    """
    return jnp.where(pa.label_sel[2] > 0.5, oracle_wtype, clf_wtype)


def reclass_interval(pa: PolicyArrays, default):
    """① Effective classifier sampling window (accesses) — the
    policy-visible reclassification knob; 0 defers to the SimParams
    default."""
    return jnp.where(pa.reclass_interval > 0.5, pa.reclass_interval,
                     jnp.asarray(default, F32))


def probe_interval(pa: PolicyArrays, default):
    """①② Effective probe cadence (accesses between forced cache-path
    probes of a bypassing warp) — policy-visible and sweepable like the
    sampling window; 0 defers to the SimParams default
    (``DEFAULT_PROBE_INTERVAL``)."""
    return jnp.where(pa.probe_interval > 0.5, pa.probe_interval,
                     jnp.asarray(default, F32))


#: effectively-unbounded window count for the online labeling mode
_NO_WINDOW_CAP = 1 << 30


def reclass_max_windows(pa: PolicyArrays):
    """① How many sampling windows may update a warp's label: 1 for the
    stale (classify-once, phase-0) mode, unbounded otherwise. The window
    machinery keeps cycling either way (EAF-style generation counting in
    ``classifier.observe``) — only the label write is gated."""
    return jnp.where(pa.label_sel[1] > 0.5, 1, _NO_WINDOW_CAP).astype(I32)


def pcal_tokens(pa: PolicyArrays, n_warps: int):
    """PCAL token assignment: a pseudo-random but fixed subset of warps,
    blind to warp type (first-come/scheduler-order in the paper)."""
    n_tokens = jnp.maximum(
        1, jnp.round(pa.pcal_frac * n_warps)).astype(I32)
    return hash_index(jnp.arange(n_warps, dtype=I32), 11, 997) < (
        997 * n_tokens // n_warps)
