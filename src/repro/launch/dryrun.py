import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (SPMD
partitioning succeeds, no sharding mismatch, no unsupported collective) and
extracts the roofline inputs:

  * compiled.memory_analysis()   -> per-device bytes (fits-in-HBM check)
  * compiled.cost_analysis()     -> raw per-device FLOPs/bytes (loop bodies
                                    counted once — see hlo_analysis)
  * hlo_analysis.analyze()       -> loop-aware per-device dot FLOPs, memory
                                    estimate, collective bytes by kind and
                                    replica-group size

Results are written one JSON per cell (restartable); `--emit-table` prints
the EXPERIMENTS.md rows.

Usage:
  python -m repro.launch.dryrun --arch qwen3_1_7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, SHAPES, OptimizerConfig,
                                get_config, shape_applicable)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.optim.optimizer import init_opt_state, make_train_step
from repro.sharding import (Logical, build_rules, sharding_ctx,
                            tree_shardings)

HW = {  # TPU v5e-class single chip
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
    "hbm_bytes": 16e9,
}


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` returns one dict on newer jax but a
    per-device LIST of dicts on jax<=0.4.x — normalize to the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _opt_cfg(cfg) -> OptimizerConfig:
    # bf16 moments for >20B-param models: the optimizer-state lever that
    # fits grok-1-314b / qwen1.5-110b training on a 256-chip pod
    big = cfg.num_params > 20e9
    return OptimizerConfig(moment_dtype="bfloat16" if big else "float32")


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    """Returns (fn, example_args, in_shardings, donate) for the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = build_rules(mesh)

    param_shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    plog = model.logical_params()
    pshard = tree_shardings(plog, param_shapes, mesh, rules)

    batch_specs = model.input_specs(shape)
    blog = model.batch_logical(shape)
    bshard = tree_shardings(blog, batch_specs, mesh, rules)

    if shape.kind == "train":
        ocfg = _opt_cfg(cfg)
        opt_shapes = jax.eval_shape(
            lambda p: init_opt_state(p, ocfg), param_shapes)
        olog = {"m": plog, "v": plog, "count": Logical()}
        if "err" in opt_shapes:
            olog["err"] = plog
        oshard = tree_shardings(olog, opt_shapes, mesh, rules)
        step = make_train_step(model, ocfg)
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     donate_argnums=(0, 1))
        args = (param_shapes, opt_shapes, batch_specs)
    elif shape.kind == "prefill":
        cache_shapes = model.cache_specs(shape)
        clog = model.cache_logical(shape.global_batch, shape)
        cshard = tree_shardings(clog, cache_shapes, mesh, rules)

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard, cshard),
                     donate_argnums=(2,))
        args = (param_shapes, batch_specs, cache_shapes)
    else:  # decode
        cache_shapes = model.cache_specs(shape)
        clog = model.cache_logical(shape.global_batch, shape)
        cshard = tree_shardings(clog, cache_shapes, mesh, rules)
        tok_shard = tree_shardings(
            {"tokens": Logical("batch", None)},
            {"tokens": batch_specs["tokens"]}, mesh, rules)["tokens"]

        def decode_step(params, tokens, cache):
            return model.decode(params, tokens, cache)

        fn = jax.jit(decode_step, in_shardings=(pshard, tok_shard, cshard),
                     donate_argnums=(2,))
        args = (param_shapes, batch_specs["tokens"], cache_shapes)
    return cfg, shape, mesh, rules, fn, args


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs per step (global), per the brief."""
    n = cfg.num_active_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": reason}
    cfg, shape, mesh, rules, fn, args = build_cell(arch, shape_name,
                                                   multi_pod)
    with mesh, sharding_ctx(mesh, rules):
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    summ = hlo_analysis.analyze(compiled.as_text())
    n_dev = mesh.devices.size

    mf = model_flops(cfg, shape)
    hlo_flops_global = summ.dot_flops * n_dev
    per_dev_bytes = ma.argument_size_in_bytes + ma.temp_size_in_bytes
    # loop-corrected HBM traffic: scale XLA's fusion-aware byte count by the
    # flops loop-correction ratio (cost_analysis counts loop bodies once);
    # the op-output sum from hlo_analysis is kept as an upper bound.
    raw_flops = ca.get("flops", 0.0) or 0.0
    loop_ratio = (summ.dot_flops / raw_flops) if raw_flops else 1.0
    mem_scaled = (ca.get("bytes accessed", 0.0) or 0.0) * max(loop_ratio, 1.0)
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_live_bytes": per_dev_bytes,
            "fits_16gb": bool(per_dev_bytes < HW["hbm_bytes"]),
        },
        "cost_analysis_raw": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "hlo": {
            "dot_flops_per_dev": summ.dot_flops,
            "mem_bytes_per_dev": mem_scaled,
            "mem_bytes_upper_per_dev": summ.mem_bytes,
            "loop_ratio": loop_ratio,
            "coll_bytes_per_dev": summ.coll_total,
            "coll_by_kind": summ.coll_bytes,
            "coll_by_group": {f"{k}@{g}": v for (k, g), v in
                              summ.coll_by_group.items()},
            "cross_pod_bytes": summ.cross_pod_bytes(),
            "n_while": summ.n_while,
            "trip_counts": summ.trip_counts,
        },
        "model_flops_global": mf,
        "useful_ratio": mf / hlo_flops_global if hlo_flops_global else None,
        "roofline": roofline_terms(summ, mem_scaled, mf, n_dev),
    }
    return out


def roofline_terms(summ, mem_scaled, mf_global, n_dev) -> dict:
    compute_s = summ.dot_flops / HW["peak_flops_bf16"]
    memory_s = mem_scaled / HW["hbm_bw"]
    coll_s = summ.coll_total / HW["ici_bw"]
    dom = max((compute_s, "compute"), (memory_s, "memory"),
              (coll_s, "collective"))[1]
    bound = max(compute_s, memory_s, coll_s)
    mfu_bound = (mf_global / n_dev / HW["peak_flops_bf16"]) / bound \
        if bound else 0.0
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dom,
        "roofline_fraction": mfu_bound,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape_name}__{'mp' if mp else 'sp'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached ] {tag}")
                    continue
                try:
                    res = run_cell(arch, shape_name, mp)
                except Exception as e:  # noqa: BLE001
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f"compile={res['compile_s']}s "
                             f"mem/dev={res['memory']['per_device_live_bytes']/1e9:.2f}GB "
                             f"dom={r['dominant']} "
                             f"frac={r['roofline_fraction']:.3f}")
                elif status == "error":
                    extra = res["error"][:120]
                else:
                    extra = res["reason"][:60]
                print(f"[{status:7s}] {tag} {extra}", flush=True)
    print(f"done; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
