"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before any jax import; tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — for tests."""
    n = data * model
    avail = len(jax.devices())
    if n > avail:
        raise ValueError(
            f"make_local_mesh(data={data}, model={model}) needs {n} "
            f"device(s) but only {avail} are available; lower the mesh "
            "or start the process with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "(must be set before the first jax import)")
    devs = np.asarray(jax.devices()[:n]).reshape(data, model)
    return Mesh(devs, ("data", "model"))
