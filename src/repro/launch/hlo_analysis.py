"""Post-SPMD HLO analysis — the dry-run "profiler".

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified on
this container: a 64-layer scanned train step reports ~1/64 of the unrolled
FLOPs), so scanned-layer models need loop-aware rollup. This module parses
``compiled.as_text()`` into a computation call graph, extracts while-loop
trip counts from the loop-condition constants, and rolls up:

  * dot FLOPs (2 * prod(output) * contracted sizes — matmul-dominated
    models; elementwise FLOPs are second-order and reported via the raw
    cost_analysis column),
  * memory-traffic estimate (sum of output bytes of top-level non-trivial
    ops, x2 for read+write — post-fusion this approximates HBM traffic),
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), using each op's max(result, operand)
    bytes, with replica-group size recorded so pod-crossing traffic can be
    split out.

All numbers are PER DEVICE (the HLO is the per-partition module).
Validated against an unrolled compile of the same model in tests.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes_and_elems(type_str: str) -> Tuple[int, int]:
    """Total bytes and element count across all arrays in a type string."""
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_type: str
    line: str


def _split_operands(s: str) -> List[str]:
    """Split an operand list on top-level commas only — shapes like
    ``f32[4,32]{1,0}`` and tuple types contain commas of their own."""
    parts: List[str] = []
    depth, cur = 0, []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operand_type(operand: str, types: Dict[str, str]) -> str:
    """Type string of one operand. Newer XLA prints bare names
    (``%get-tuple-element.4``); older XLA (jax<=0.4.x) prints the type
    inline (``f32[4,32]{1,0} %get-tuple-element.4``) — prefer the inline
    type, fall back to the name lookup."""
    operand = operand.strip()
    parts = operand.rsplit(None, 1)
    if len(parts) == 2 and _SHAPE_RE.search(parts[0]):
        return parts[0]
    return types.get(operand.lstrip("%"), "")


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    # local (non-rolled-up) numbers
    dot_flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_by_group: Dict[Tuple[str, int], float] = dataclasses.field(
        default_factory=dict)
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # callee name -> multiplier


# type is matched non-greedily up to the first `opcode(` token — tuple
# types (which may contain /*index=N*/ comments) never have a bare
# `word(` inside, so the first such token is the opcode.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_DIMS_ATTR = re.compile(r"(\w+_contracting_dims)=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def parse_hlo(text: str) -> Dict[str, Computation]:
    """Computation headers are non-indented lines ending in '{' that start
    with ENTRY or %name; instructions are indented '%name = ...' lines."""
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if line and not line.startswith(" ") and line.endswith("{"):
            s = line.strip()
            is_entry = s.startswith("ENTRY")
            if is_entry:
                s = s[len("ENTRY"):].strip()
            if s.startswith("%") or is_entry:
                name = re.split(r"[\s(]", s.lstrip("%"), maxsplit=1)[0]
                cur = Computation(name, [])
                comps[name] = cur
                if is_entry:
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, out_type, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, opcode, out_type, line))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _analyze_computation(comp: Computation, param_types: Dict[str, str]):
    """Populate local stats + call edges for one computation."""
    # map instr name -> out type, for operand byte lookups
    types = dict(param_types)
    for ins in comp.instrs:
        types[ins.name] = ins.out_type

    for ins in comp.instrs:
        op = ins.opcode
        out_b, out_e = _shape_bytes_and_elems(ins.out_type)

        if op == "dot":
            # flops = 2 * prod(output dims) * prod(contracting dims of lhs)
            mm = re.search(r"dot\(([^)]*)\)", ins.line)
            operands = _split_operands(mm.group(1)) if mm else []
            cdims = dict(_DIMS_ATTR.findall(ins.line))
            lhs_c = cdims.get("lhs_contracting_dims", "")
            contracted = 1
            if operands and lhs_c:
                lhs_t = _operand_type(operands[0], types)
                sm = _SHAPE_RE.search(lhs_t)
                if sm and sm.group(2):
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for ci in lhs_c.split(","):
                        if ci and int(ci) < len(dims):
                            contracted *= dims[int(ci)]
            comp.dot_flops += 2.0 * out_e * contracted

        if op.startswith("while"):
            mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
            mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
            if mb:
                comp.calls.append((mb.group(1), -1.0))  # trip filled later
                comp._while_conds = getattr(comp, "_while_conds", [])
                comp._while_conds.append((mb.group(1),
                                          mc.group(1) if mc else None))
        elif op in ("fusion", "call", "custom-call", "conditional",
                    "reduce", "sort", "scatter", "map", "reduce-window",
                    "select-and-scatter", "all-reduce", "reduce-scatter"):
            # called computation's FLOPs count once, but its internal ops
            # do NOT touch HBM (fused into registers/VMEM): mem_mult = 0
            for cname in _CALLED_RE.findall(ins.line):
                if "body=" not in ins.line and "condition=" not in ins.line:
                    comp.calls.append((cname, 1.0, 0.0))

        for kind in _COLLECTIVES:
            if op.startswith(kind) and not op.endswith("-done"):
                # wire-volume estimate: max of result/operand bytes
                mm = re.search(rf"{kind}[\w\-]*\((.*?)\)", ins.line)
                in_b = 0
                if mm:
                    for o in _split_operands(mm.group(1)):
                        tb, _ = _shape_bytes_and_elems(
                            _operand_type(o, types))
                        in_b += tb
                vol = float(max(out_b, in_b))
                comp.coll_bytes[kind] = comp.coll_bytes.get(kind, 0.0) + vol
                gm = _GROUPS_RE.search(ins.line)
                group_size = 0
                if gm:
                    group_size = int(gm.group(2))
                else:
                    gb = _GROUPS_BRACE.search(ins.line)
                    if gb:
                        group_size = len(gb.group(1).split(","))
                k = (kind, group_size)
                comp.coll_by_group[k] = comp.coll_by_group.get(k, 0.0) + vol
                break

        if op not in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "reshape", "copy-done", "copy-start",
                      "after-all", "partition-id"):
            comp.mem_bytes += 2.0 * out_b


def _trip_count(cond: Optional[Computation]) -> float:
    """Extract the trip count from a counted-loop condition computation."""
    if cond is None:
        return 1.0
    best = None
    for ins in cond.instrs:
        m = _CONST_RE.search(ins.line)
        if m:
            v = int(m.group(1))
            best = v if best is None else max(best, v)
    return float(best) if best else 1.0


@dataclasses.dataclass
class HloSummary:
    dot_flops: float
    mem_bytes: float
    coll_bytes: Dict[str, float]
    coll_by_group: Dict[Tuple[str, int], float]
    coll_total: float
    n_while: int
    trip_counts: List[float]

    def cross_pod_bytes(self, intra_pod_group_sizes=(1, 16, 256)) -> float:
        """Collective bytes on groups that span pods. On the 512-device
        (2,16,16) mesh: model-axis groups = 16, data x model = 256 are
        intra-pod; 32 (pod x data) and 512 (global) cross pods."""
        return sum(v for (k, gs), v in self.coll_by_group.items()
                   if gs not in intra_pod_group_sizes)


def analyze(text: str) -> HloSummary:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    for c in comps.values():
        if not hasattr(c, "_analyzed"):
            _analyze_computation(c, {})
            c._analyzed = True

    trips: List[float] = []

    # resolve while multipliers: calls are (name, flops_mult, mem_mult)
    for c in comps.values():
        conds = getattr(c, "_while_conds", [])
        cond_of = dict(conds)
        new_calls = []
        for entry_call in c.calls:
            name, mult = entry_call[0], entry_call[1]
            mem_mult = entry_call[2] if len(entry_call) > 2 else mult
            if mult < 0:
                cond_name = cond_of.get(name)
                t = _trip_count(comps.get(cond_name)) if cond_name else 1.0
                trips.append(t)
                new_calls.append((name, t, t))
            else:
                new_calls.append((name, mult, mem_mult))
        c.calls = new_calls

    memo: Dict[str, Tuple[float, float, Dict, Dict]] = {}

    def roll(name: str, depth=0):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 64:
            return (0.0, 0.0, {}, {})
        fl, mb = c.dot_flops, c.mem_bytes
        cb = dict(c.coll_bytes)
        cg = dict(c.coll_by_group)
        for callee, mult, mem_mult in c.calls:
            if callee == name:
                continue
            cfl, cmb, ccb, ccg = roll(callee, depth + 1)
            fl += mult * cfl
            mb += mem_mult * cmb
            for k, v in ccb.items():
                cb[k] = cb.get(k, 0.0) + mult * v
            for k, v in ccg.items():
                cg[k] = cg.get(k, 0.0) + mult * v
        memo[name] = (fl, mb, cb, cg)
        return memo[name]

    fl, mb, cb, cg = roll(entry.name)
    return HloSummary(dot_flops=fl, mem_bytes=mb, coll_bytes=cb,
                      coll_by_group=cg, coll_total=sum(cb.values()),
                      n_while=len(trips), trip_counts=sorted(trips)[-8:])
