"""MeDiC KV-block-pool manager (altitude B — the production mechanism).

Maps the paper's four components onto the two-tier KV store of a TPU
serving runtime (see DESIGN.md §2 table):

  ① sequence-type identification — per-sequence residency hit/access
    counters via ``repro.core.classifier``'s taxonomy (the same code that
    classifies warps in the altitude-A simulator);
  ② bypass — blocks fetched for mostly/all-miss sequences are *streamed*:
    landed for the step, never retained, so they neither pollute the pool
    nor occupy fetch-queue slots for retained traffic;
  ③ insertion — retained blocks join a pool-wide RRIP order seeded by the
    owner sequence's type (mostly-hit near-MRU, balanced mid, miss-class
    near-LRU);
  ④ two-queue fetch scheduler — host->HBM block fetches from mostly/all-hit
    sequences go to a strict-priority high queue; FCFS within queues over a
    modelled transfer engine (latency + bandwidth occupancy), mirroring the
    paper's two-queue FR-FCFS memory controller.

The ②③④ decisions come from the shared branchless policy engine: a
``PoolConfig.policy`` preset is lowered to ``repro.policy.DecisionTables``
(numpy lookups evaluated once through the same ops the simulator jits), so
both altitudes share one mechanism implementation.

State is held in fixed-capacity numpy arrays (one row per budgeted block:
owner key, RRIP rank, owner type, insertion sequence), so lookup,
insertion-pressure aging, and victim selection are vectorized — the
dict-based original survives as ``serving.pool_ref.DictPoolManager`` and a
parity test pins this implementation to it.

The manager tracks real block residency against a device-HBM budget; block
payloads live in the engine's cache arrays and are offloaded/restored
through a host store so the data path is real, while fetch *timing* is
modelled (CPU container).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import warp_types as WT
from repro.policy import DecisionTables, Policy, to_arrays


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    budget_blocks: int               # device-HBM KV budget (in blocks)
    block_tokens: int = 16
    rrip_max: int = 7
    sampling_interval: int = 32      # block-accesses per re-classification
    mostly_hit_threshold: float = 0.8
    mostly_miss_threshold: float = 0.2
    # transfer-engine model (per block)
    fetch_latency: float = 8.0       # fixed host->HBM latency (engine ticks)
    fetch_occupancy: float = 1.0     # transfer-engine occupancy per block
    policy: str = "medic"            # "medic" | "lru"


# PoolConfig.policy presets, expressed in the unified policy engine
POOL_POLICIES: Dict[str, Policy] = {
    "medic": Policy("pool-medic", bypass="medic", insertion="medic",
                    scheduler="medic"),
    "lru": Policy("pool-lru"),
}


class MedicPoolManager:
    """Residency + policy control plane. One instance per engine.

    Array-backed: residency is a fixed-capacity table of ``budget_blocks``
    rows; a free row has owner slot -1. Victim selection replicates the
    reference dict semantics (max rank, earliest-inserted tie-break) via
    an insertion-sequence column, and insertion-pressure aging is one
    vectorized clamp instead of a per-key loop.
    """

    def __init__(self, cfg: PoolConfig, max_seqs: int, on_evict=None):
        self.cfg = cfg
        self.max_seqs = max_seqs
        self.on_evict = on_evict or (lambda key: None)
        if cfg.policy not in POOL_POLICIES:
            raise ValueError(f"unknown pool policy {cfg.policy!r}")
        if cfg.budget_blocks < 1:
            raise ValueError("budget_blocks must be >= 1")
        self.tables = DecisionTables.from_arrays(
            to_arrays(POOL_POLICIES[cfg.policy]), cfg.rrip_max)
        # residency table: one row per budgeted block
        cap = cfg.budget_blocks
        self._slot = np.full(cap, -1, np.int64)    # owner seq slot (-1 free)
        self._blk = np.full(cap, -1, np.int64)     # block index within owner
        self._rank = np.zeros(cap, np.int64)       # RRIP rank
        self._otype = np.full(cap, WT.BALANCED, np.int64)
        self._ins_seq = np.zeros(cap, np.int64)    # insertion order tie-break
        self._next_seq = 0
        self._row: Dict[Tuple[int, int], int] = {}  # key -> row (O(1) find)
        self._free = list(range(cap - 1, -1, -1))   # free rows (O(1) alloc)
        # classifier counters per slot (incl. pseudo-slots) (①)
        self.hits = np.zeros(max_seqs, np.int64)
        self.accesses = np.zeros(max_seqs, np.int64)
        self.win_hits = np.zeros(max_seqs, np.int64)
        self.win_acc = np.zeros(max_seqs, np.int64)
        self.seq_type = np.full(max_seqs, WT.BALANCED, np.int64)
        self.ratio = np.full(max_seqs, 0.5, np.float64)
        # two-queue transfer engine (④)
        self.hp_free = 0.0
        self.lp_free = 0.0
        # metrics
        self.fetches = 0
        self.fetch_bytes_blocks = 0
        self.qdelays: List[float] = []
        self.evictions_by_type = np.zeros(WT.NUM_TYPES, np.int64)
        self.bypassed_blocks = 0

    # -- residency table helpers ---------------------------------------------

    def _find(self, key: Tuple[int, int]) -> int:
        """Row index of `key`, or -1 (hash index kept beside the arrays)."""
        return self._row.get((int(key[0]), int(key[1])), -1)

    def is_resident(self, key: Tuple[int, int]) -> bool:
        return self._find(key) >= 0

    @property
    def resident(self) -> Dict[Tuple[int, int], int]:
        """Residency as a key->rank dict (insertion order), for
        introspection and the dict-parity tests."""
        rows = np.nonzero(self._slot >= 0)[0]
        rows = rows[np.argsort(self._ins_seq[rows], kind="stable")]
        return {(int(self._slot[i]), int(self._blk[i])): int(self._rank[i])
                for i in rows}

    # -- classification (①) -------------------------------------------------

    def _observe(self, slot: int, hit: bool):
        self.hits[slot] += hit
        self.accesses[slot] += 1
        self.win_hits[slot] += hit
        self.win_acc[slot] += 1
        if self.win_acc[slot] >= self.cfg.sampling_interval:
            r = self.win_hits[slot] / max(self.win_acc[slot], 1)
            self.ratio[slot] = r
            self.seq_type[slot] = WT.classify_np(
                r, int(self.win_acc[slot]),
                mostly_hit_threshold=self.cfg.mostly_hit_threshold,
                mostly_miss_threshold=self.cfg.mostly_miss_threshold,
                min_samples=1)
            self.win_hits[slot] = 0
            self.win_acc[slot] = 0

    def reset_slot(self, slot: int):
        """New sequence admitted into the slot: drop its blocks + counters."""
        mine = np.nonzero(self._slot == slot)[0]
        self._slot[mine] = -1
        self._blk[mine] = -1
        self._free.extend(int(r) for r in mine)
        for key in [k for k in self._row if k[0] == slot]:
            del self._row[key]
        self.hits[slot] = self.accesses[slot] = 0
        self.win_hits[slot] = self.win_acc[slot] = 0
        self.seq_type[slot] = WT.BALANCED
        self.ratio[slot] = 0.5

    # -- the per-step residency transaction ----------------------------------

    def access(self, slot: int, blocks: List[int], now: float,
               resident_key: Optional[Tuple[int, int]] = None
               ) -> Tuple[float, List[int]]:
        """A decode step for sequence `slot` needs `blocks`. Returns
        (ready_time, fetched_block_list). Updates residency per policy.
        `resident_key` overrides the residency key (shared-prefix blocks
        live under a pseudo-slot while counting toward `slot`'s ratio)."""
        cfg = self.cfg
        tb = self.tables
        stype = int(self.seq_type[slot])
        ready = now
        fetched = []
        for blk in blocks:
            key = resident_key if resident_key is not None else (slot, blk)
            row = self._find(key)
            self._observe(slot, row >= 0)
            if row >= 0:
                # promotion: hit blocks move to rank 0 (MRU analogue)
                self._rank[row] = 0
                continue
            # ---- miss -> fetch through the two-queue scheduler (④) -------
            self.fetches += 1
            self.fetch_bytes_blocks += 1
            fetched.append(blk)
            if tb.hp_by_type[stype]:
                t0 = max(self.hp_free, now)
                self.hp_free = t0 + cfg.fetch_occupancy
            else:
                t0 = max(self.lp_free, self.hp_free, now)
                self.lp_free = t0 + cfg.fetch_occupancy
            self.qdelays.append(t0 - now)
            ready = max(ready, t0 + cfg.fetch_latency)
            # ---- insertion / bypass (②③) ---------------------------------
            if tb.bypass_by_type[stype]:
                self.bypassed_blocks += 1
                continue  # streamed: not retained
            self._insert(key, int(tb.rank_by_type[stype]), stype)
        return ready, fetched

    def _insert(self, key, rank: int, stype: int):
        cfg = self.cfg
        n = len(self._row)                       # resident count, O(1)
        while n >= cfg.budget_blocks:
            self._evict_one()
            n -= 1
        # age everyone mildly on insertion pressure (RRIP-flavoured) —
        # one vectorized clamp, and only when actually near budget
        if n >= cfg.budget_blocks - 1:
            valid = self._slot >= 0
            self._rank[valid] = np.minimum(self._rank[valid] + 1,
                                           cfg.rrip_max)
        row = self._find(key)
        if row < 0:
            row = self._free.pop()
            self._slot[row], self._blk[row] = key
            self._ins_seq[row] = self._next_seq
            self._next_seq += 1
            self._row[(int(key[0]), int(key[1]))] = row
        self._rank[row] = rank
        self._otype[row] = stype

    def _evict_one(self):
        """Evict the max-rank resident; ties break to the earliest-inserted
        (the reference dict's iteration order)."""
        valid = self._slot >= 0
        ranked = np.where(valid, self._rank, -1)
        cand = np.nonzero(ranked == ranked.max())[0]
        victim = int(cand[np.argmin(self._ins_seq[cand])])
        vt = int(self._otype[victim])
        self.evictions_by_type[vt] += 1
        key = (int(self._slot[victim]), int(self._blk[victim]))
        self._slot[victim] = -1
        self._blk[victim] = -1
        self._row.pop(key, None)
        self._free.append(victim)
        self.on_evict(key)

    def insert_prefill(self, key, stype: int):
        """Blocks produced on-device at prefill: no fetch cost, but they
        enter the pool under the insertion/bypass policy."""
        tb = self.tables
        if tb.bypass_by_type[stype]:
            self.bypassed_blocks += 1
            self.on_evict(key)   # streamed immediately (not retained)
            return
        self._insert(key, int(tb.rank_by_type[stype]), stype)

    # -- metrics --------------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        ratios = np.where(self.accesses > 0,
                          self.hits / np.maximum(self.accesses, 1), np.nan)
        return {
            "fetches": self.fetches,
            "bypassed_blocks": self.bypassed_blocks,
            "mean_qdelay": float(np.mean(self.qdelays)) if self.qdelays else 0.0,
            "p99_qdelay": float(np.percentile(self.qdelays, 99)) if self.qdelays else 0.0,
            "qdelays": np.asarray(self.qdelays),
            "seq_hit_ratio": ratios,
            "seq_type": self.seq_type.copy(),
            "resident_blocks": int((self._slot >= 0).sum()),
            "evictions_by_type": self.evictions_by_type.copy(),
        }
