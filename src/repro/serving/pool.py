"""MeDiC KV-block-pool manager (altitude B — the production mechanism).

Maps the paper's four components onto the two-tier KV store of a TPU
serving runtime (see DESIGN.md §2 table):

  ① sequence-type identification — per-sequence residency hit/access
    counters via ``repro.core.classifier``'s taxonomy (the same code that
    classifies warps in the altitude-A simulator);
  ② bypass — blocks fetched for mostly/all-miss sequences are *streamed*:
    landed for the step, never retained, so they neither pollute the pool
    nor occupy fetch-queue slots for retained traffic;
  ③ insertion — retained blocks join a pool-wide RRIP order seeded by the
    owner sequence's type (mostly-hit near-MRU, balanced mid, miss-class
    near-LRU);
  ④ two-queue fetch scheduler — host->HBM block fetches from mostly/all-hit
    sequences go to a strict-priority high queue; FCFS within queues over a
    modelled transfer engine (latency + bandwidth occupancy), mirroring the
    paper's two-queue FR-FCFS memory controller.

The ②③④ decisions come from the shared branchless policy engine: a
``PoolConfig.policy`` preset is lowered to ``repro.policy.DecisionTables``
(numpy lookups evaluated once through the same ops the simulator jits), so
both altitudes share one mechanism implementation.

State is held in fixed-capacity numpy arrays (one row per budgeted block:
owner key, RRIP rank, owner type, insertion sequence), so lookup,
insertion-pressure aging, and victim selection are vectorized — the
dict-based original survives as ``serving.pool_ref.DictPoolManager`` and a
parity test pins this implementation to it.

The manager tracks real block residency against a device-HBM budget; block
payloads live in the engine's cache arrays and are offloaded/restored
through a host store so the data path is real, while fetch *timing* is
modelled (CPU container).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import warp_types as WT
from repro.policy import DecisionTables, Policy, to_arrays

# (slot, blk) keys packed as one int64 code for vectorized lookup; block
# indices are bounded by max_len / block_tokens (tens), far below this
_BLK_STRIDE = 1 << 21


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    budget_blocks: int               # device-HBM KV budget (in blocks)
    block_tokens: int = 16
    rrip_max: int = 7
    sampling_interval: int = 32      # block-accesses per re-classification
    mostly_hit_threshold: float = 0.8
    mostly_miss_threshold: float = 0.2
    # transfer-engine model (per block)
    fetch_latency: float = 8.0       # fixed host->HBM latency (engine ticks)
    fetch_occupancy: float = 1.0     # transfer-engine occupancy per block
    policy: str = "medic"            # "medic" | "lru"


# PoolConfig.policy presets, expressed in the unified policy engine
POOL_POLICIES: Dict[str, Policy] = {
    "medic": Policy("pool-medic", bypass="medic", insertion="medic",
                    scheduler="medic"),
    "lru": Policy("pool-lru"),
}


class MedicPoolManager:
    """Residency + policy control plane. One instance per engine.

    Array-backed: residency is a fixed-capacity table of ``budget_blocks``
    rows; a free row has owner slot -1. Victim selection replicates the
    reference dict semantics (max rank, earliest-inserted tie-break) via
    an insertion-sequence column, and insertion-pressure aging is one
    vectorized clamp instead of a per-key loop.
    """

    def __init__(self, cfg: PoolConfig, max_seqs: int, on_evict=None,
                 policy: Optional[Policy] = None):
        self.cfg = cfg
        self.max_seqs = max_seqs
        self.on_evict = on_evict or (lambda key: None)
        if cfg.budget_blocks < 1:
            raise ValueError("budget_blocks must be >= 1")
        # a Policy object (the unified engine's preset) overrides the
        # cfg.policy string: this is how the serving simulator sweeps the
        # full labeling ladder (LRU / MeDiC / stale / oracle) through one
        # pool implementation
        if policy is None:
            if cfg.policy not in POOL_POLICIES:
                raise ValueError(f"unknown pool policy {cfg.policy!r}")
            policy = POOL_POLICIES[cfg.policy]
        self.policy = policy
        self.tables = DecisionTables.from_arrays(
            to_arrays(policy), cfg.rrip_max)
        # ① labeling mode + effective reclassification window: ``stale``
        # freezes each sequence's first classified label until the slot
        # is reset; ``oracle`` pins labels set via ``set_oracle_type``
        self.label_mode = policy.labeling
        self._interval = int(policy.reclass_interval
                             or cfg.sampling_interval)
        # residency table: one row per budgeted block
        cap = cfg.budget_blocks
        self._slot = np.full(cap, -1, np.int64)    # owner seq slot (-1 free)
        self._blk = np.full(cap, -1, np.int64)     # block index within owner
        self._rank = np.zeros(cap, np.int64)       # RRIP rank
        self._otype = np.full(cap, WT.BALANCED, np.int64)
        self._ins_seq = np.zeros(cap, np.int64)    # insertion order tie-break
        self._next_seq = 0
        self._row: Dict[Tuple[int, int], int] = {}  # key -> row (O(1) find)
        self._free = list(range(cap - 1, -1, -1))   # free rows (O(1) alloc)
        # classifier counters per slot (incl. pseudo-slots) (①)
        self.hits = np.zeros(max_seqs, np.int64)
        self.accesses = np.zeros(max_seqs, np.int64)
        self.win_hits = np.zeros(max_seqs, np.int64)
        self.win_acc = np.zeros(max_seqs, np.int64)
        self.seq_type = np.full(max_seqs, WT.BALANCED, np.int64)
        self.ratio = np.full(max_seqs, 0.5, np.float64)
        self._label_locked = np.zeros(max_seqs, bool)
        # two-queue transfer engine (④)
        self.hp_free = 0.0
        self.lp_free = 0.0
        # metrics
        self.fetches = 0
        self.fetch_bytes_blocks = 0
        self.qdelays: List[float] = []
        self.evictions_by_type = np.zeros(WT.NUM_TYPES, np.int64)
        self.bypassed_blocks = 0

    # -- residency table helpers ---------------------------------------------

    def _find(self, key: Tuple[int, int]) -> int:
        """Row index of `key`, or -1 (hash index kept beside the arrays)."""
        return self._row.get((int(key[0]), int(key[1])), -1)

    def is_resident(self, key: Tuple[int, int]) -> bool:
        return self._find(key) >= 0

    @property
    def resident(self) -> Dict[Tuple[int, int], int]:
        """Residency as a key->rank dict (insertion order), for
        introspection and the dict-parity tests."""
        rows = np.nonzero(self._slot >= 0)[0]
        rows = rows[np.argsort(self._ins_seq[rows], kind="stable")]
        return {(int(self._slot[i]), int(self._blk[i])): int(self._rank[i])
                for i in rows}

    # -- classification (①) -------------------------------------------------

    def _observe(self, slot: int, hit: bool):
        self.hits[slot] += hit
        self.accesses[slot] += 1
        self.win_hits[slot] += hit
        self.win_acc[slot] += 1
        if self.win_acc[slot] >= self._interval:
            r = self.win_hits[slot] / max(self.win_acc[slot], 1)
            self.ratio[slot] = r
            newt = WT.classify_np(
                r, int(self.win_acc[slot]),
                mostly_hit_threshold=self.cfg.mostly_hit_threshold,
                mostly_miss_threshold=self.cfg.mostly_miss_threshold,
                min_samples=1)
            self._relabel(slot, newt)
            self.win_hits[slot] = 0
            self.win_acc[slot] = 0

    def _relabel(self, slot: int, newt: int):
        """Apply one window's classification under the labeling mode."""
        if self.label_mode == "oracle":
            return                      # pinned via set_oracle_type
        if self.label_mode == "stale" and self._label_locked[slot]:
            return                      # first classified label sticks
        self.seq_type[slot] = newt
        self._label_locked[slot] = True

    def set_oracle_type(self, slot: int, stype: int):
        """Pin the slot's label to ground truth (``label_mode="oracle"``:
        set at admission from the request's true class; ``_observe``
        keeps counting stats but never relabels)."""
        self.seq_type[slot] = stype
        self._label_locked[slot] = True

    def reset_slot(self, slot: int):
        """New sequence admitted into the slot: drop its blocks + counters."""
        mine = np.nonzero(self._slot == slot)[0]
        self._slot[mine] = -1
        self._blk[mine] = -1
        self._free.extend(int(r) for r in mine)
        for key in [k for k in self._row if k[0] == slot]:
            del self._row[key]
        self.hits[slot] = self.accesses[slot] = 0
        self.win_hits[slot] = self.win_acc[slot] = 0
        self.seq_type[slot] = WT.BALANCED
        self.ratio[slot] = 0.5
        self._label_locked[slot] = False

    # -- the per-step residency transaction ----------------------------------

    def access(self, slot: int, blocks: List[int], now: float,
               resident_key: Optional[Tuple[int, int]] = None
               ) -> Tuple[float, List[int]]:
        """A decode step for sequence `slot` needs `blocks`. Returns
        (ready_time, fetched_block_list). Updates residency per policy.
        `resident_key` overrides the residency key (shared-prefix blocks
        live under a pseudo-slot while counting toward `slot`'s ratio)."""
        cfg = self.cfg
        tb = self.tables
        stype = int(self.seq_type[slot])
        ready = now
        fetched = []
        for blk in blocks:
            key = resident_key if resident_key is not None else (slot, blk)
            row = self._find(key)
            self._observe(slot, row >= 0)
            if row >= 0:
                # promotion: hit blocks move to rank 0 (MRU analogue)
                self._rank[row] = 0
                continue
            # ---- miss -> fetch through the two-queue scheduler (④) -------
            self.fetches += 1
            self.fetch_bytes_blocks += 1
            fetched.append(blk)
            if tb.hp_by_type[stype]:
                t0 = max(self.hp_free, now)
                self.hp_free = t0 + cfg.fetch_occupancy
            else:
                t0 = max(self.lp_free, self.hp_free, now)
                self.lp_free = t0 + cfg.fetch_occupancy
            self.qdelays.append(t0 - now)
            ready = max(ready, t0 + cfg.fetch_latency)
            # ---- insertion / bypass (②③) ---------------------------------
            if tb.bypass_by_type[stype]:
                self.bypassed_blocks += 1
                continue  # streamed: not retained
            self._insert(key, int(tb.rank_by_type[stype]), stype)
        return ready, fetched

    # -- batched residency transaction (one step, all active slots) ----------

    def access_batch(self, owner: np.ndarray, kslot: np.ndarray,
                     kblk: np.ndarray, now: float
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """One serving step's residency transactions for every active
        slot at once. ``owner[q]`` is the sequence charged for access
        ``q`` (sorted ascending — slot-major order); ``(kslot, kblk)``
        is its residency key (shared-prefix blocks live under a
        pseudo-slot). Returns ``(slots, ready)``: the distinct owners in
        order and each one's fetch-ready time.

        Semantics are EXACTLY the sequential reference — calling
        ``access(owner[q], [kblk[q]], now, resident_key=...)`` for q in
        order, the call pattern ``ServeEngine.run`` makes — but the
        dominant all-hit traffic is handled in vectorized runs: one
        residency lookup for the whole batch (packed-code searchsorted
        against a step-start snapshot), one rank-promotion scatter and a
        closed-form multi-window classifier advance per run. Only
        segments with a miss (or whose snapshot was invalidated by a
        same-step eviction/insertion from an earlier slot) drop to the
        per-key path, so those interleavings stay bit-exact too.
        """
        owner = np.asarray(owner, np.int64)
        kslot = np.asarray(kslot, np.int64)
        kblk = np.asarray(kblk, np.int64)
        n = owner.size
        if n == 0:
            return np.empty(0, np.int64), np.empty(0, np.float64)
        cut = np.nonzero(np.diff(owner))[0] + 1
        starts = np.concatenate(([0], cut))
        ends = np.concatenate((cut, [n]))
        seg_owner = owner[starts].copy()
        ready = np.full(len(seg_owner), float(now))
        # step-start residency snapshot, packed-code sorted for lookup
        valid = np.nonzero(self._slot >= 0)[0]
        codes = self._slot[valid] * _BLK_STRIDE + self._blk[valid]
        order = np.argsort(codes)
        scodes, srows = codes[order], valid[order]
        qcodes = kslot * _BLK_STRIDE + kblk
        if len(scodes):
            pos = np.minimum(np.searchsorted(scodes, qcodes),
                             len(scodes) - 1)
            hit = scodes[pos] == qcodes
            hit_row = np.where(hit, srows[pos], -1)
        else:
            hit = np.zeros(n, bool)
            hit_row = np.full(n, -1, np.int64)
        cum = np.concatenate(([0], np.cumsum(hit)))
        seg_allhit = (cum[ends] - cum[starts]) == (ends - starts)
        # keys whose residency changed since the snapshot (same-step
        # evictions/insertions by earlier slots): code -> row or -1
        changed: Dict[int, int] = {}
        prev_evict = self.on_evict

        def _tracking_evict(key):
            changed[int(key[0]) * _BLK_STRIDE + int(key[1])] = -1
            prev_evict(key)

        si, n_seg = 0, len(seg_owner)
        while si < n_seg:
            if seg_allhit[si]:
                sj = si
                while sj < n_seg and seg_allhit[sj]:
                    sj += 1
                qs, qe = starts[si], ends[sj - 1]
                rows = hit_row[qs:qe]
                if changed:
                    ch = np.fromiter(changed, np.int64, len(changed))
                    bad = np.isin(qcodes[qs:qe], ch)
                    if bad.any():
                        # an earlier slot's eviction (or re-insertion of
                        # a shared block) moved keys in this run: demote
                        # the affected segments to the per-key path
                        badcum = np.concatenate(([0], np.cumsum(bad)))
                        for k in range(si, sj):
                            b0, b1 = starts[k] - qs, ends[k] - qs
                            if badcum[b1] > badcum[b0]:
                                seg_allhit[k] = False
                        continue
                self._rank[rows] = 0
                self._advance_hits(seg_owner[si:sj], ends[si:sj] -
                                   starts[si:sj])
                si = sj
            else:
                o = int(seg_owner[si])
                t = float(now)
                self.on_evict = _tracking_evict
                try:
                    for q in range(starts[si], ends[si]):
                        key = (int(kslot[q]), int(kblk[q]))
                        tq, _ = self.access(o, [int(kblk[q])], now,
                                            resident_key=key)
                        t = max(t, tq)
                        row = self._row.get(key)
                        if row is not None:
                            changed[int(qcodes[q])] = row
                finally:
                    self.on_evict = prev_evict
                ready[si] = t
                si += 1
        return seg_owner, ready

    def _advance_hits(self, slots: np.ndarray, counts: np.ndarray):
        """Classifier counters for ``counts[j]`` consecutive HIT observes
        of ``slots[j]`` — the closed form of ``_observe(slot, True)``
        repeated, including multi-window closes. ``slots`` must be
        distinct (one segment per owner, guaranteed by the sorted-owner
        segmentation in ``access_batch``)."""
        iv = self._interval
        k = np.asarray(counts, np.int64)
        a0 = self.win_acc[slots]
        h0 = self.win_hits[slots]
        tot = a0 + k
        self.hits[slots] += k
        self.accesses[slots] += k
        n_close = tot // iv
        rem = tot % iv
        closing = n_close > 0
        if closing.any():
            cs = slots[closing]
            # the first closed window carries the pre-step partial
            # counters; later ones are pure-hit (ratio 1). The LAST
            # close sets the diagnostic ratio; label updates replay the
            # per-window order (stale locks on the first close).
            first_r = (h0[closing] + (iv - a0[closing])) / iv
            last_r = np.where(n_close[closing] >= 2, 1.0, first_r)
            thr = dict(mostly_hit_threshold=self.cfg.mostly_hit_threshold,
                       mostly_miss_threshold=self.cfg.mostly_miss_threshold)
            t_first = WT._ladder_np(first_r, **thr)
            t_last = WT._ladder_np(last_r, **thr)
            self.ratio[cs] = last_r
            if self.label_mode == "online":
                self.seq_type[cs] = t_last
                self._label_locked[cs] = True
            elif self.label_mode == "stale":
                unlocked = ~self._label_locked[cs]
                self.seq_type[cs[unlocked]] = t_first[unlocked]
                self._label_locked[cs[unlocked]] = True
            # oracle: labels pinned via set_oracle_type
            self.win_hits[cs] = rem[closing]   # open window is all-hit
            self.win_acc[cs] = rem[closing]
        nc = ~closing
        if nc.any():
            self.win_hits[slots[nc]] = tot[nc] - (a0[nc] - h0[nc])
            self.win_acc[slots[nc]] = tot[nc]

    def _insert(self, key, rank: int, stype: int):
        cfg = self.cfg
        n = len(self._row)                       # resident count, O(1)
        while n >= cfg.budget_blocks:
            self._evict_one()
            n -= 1
        # age everyone mildly on insertion pressure (RRIP-flavoured) —
        # one vectorized clamp, and only when actually near budget
        if n >= cfg.budget_blocks - 1:
            valid = self._slot >= 0
            self._rank[valid] = np.minimum(self._rank[valid] + 1,
                                           cfg.rrip_max)
        row = self._find(key)
        if row < 0:
            row = self._free.pop()
            self._slot[row], self._blk[row] = key
            self._ins_seq[row] = self._next_seq
            self._next_seq += 1
            self._row[(int(key[0]), int(key[1]))] = row
        self._rank[row] = rank
        self._otype[row] = stype

    def _evict_one(self):
        """Evict the max-rank resident; ties break to the earliest-inserted
        (the reference dict's iteration order)."""
        valid = self._slot >= 0
        ranked = np.where(valid, self._rank, -1)
        cand = np.nonzero(ranked == ranked.max())[0]
        victim = int(cand[np.argmin(self._ins_seq[cand])])
        vt = int(self._otype[victim])
        self.evictions_by_type[vt] += 1
        key = (int(self._slot[victim]), int(self._blk[victim]))
        self._slot[victim] = -1
        self._blk[victim] = -1
        self._row.pop(key, None)
        self._free.append(victim)
        self.on_evict(key)

    def insert_prefill(self, key, stype: int):
        """Blocks produced on-device at prefill: no fetch cost, but they
        enter the pool under the insertion/bypass policy."""
        tb = self.tables
        if tb.bypass_by_type[stype]:
            self.bypassed_blocks += 1
            self.on_evict(key)   # streamed immediately (not retained)
            return
        self._insert(key, int(tb.rank_by_type[stype]), stype)

    # -- metrics --------------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        ratios = np.where(self.accesses > 0,
                          self.hits / np.maximum(self.accesses, 1), np.nan)
        return {
            "fetches": self.fetches,
            "bypassed_blocks": self.bypassed_blocks,
            "mean_qdelay": float(np.mean(self.qdelays)) if self.qdelays else 0.0,
            "p99_qdelay": float(np.percentile(self.qdelays, 99)) if self.qdelays else 0.0,
            "qdelays": np.asarray(self.qdelays),
            "seq_hit_ratio": ratios,
            "seq_type": self.seq_type.copy(),
            "resident_blocks": int((self._slot >= 0).sum()),
            "evictions_by_type": self.evictions_by_type.copy(),
        }
