"""Open-loop arrival processes from the splitmix64 counter RNG.

Every stream is an inhomogeneous Poisson process generated the same way:
draw a unit-rate Poisson event sequence (exponential gaps, each a pure
function of ``(stream_key, index)`` — the tracegen construction, so
streams are deterministic and seed-stackable), then warp event times
through the inverse integrated rate Λ⁻¹:

    poisson   Λ(t) = r·t                       (identity up to scale)
    bursty    Λ(t) = square-wave rate           (piecewise-linear, closed
              (hi = r·boost for duty·period)     form inverse)
    diurnal   Λ(t) = r·(t + amp·P/2π·(1−cos))   (monotone; vectorized
                                                 bisection inverse)
    closed    every arrival at t = 0            (ServeEngine parity case)

Request attributes (chat/RAG class, prompt/decode lengths, shared-prefix
id) come from dedicated counter sub-streams at index = request id, so a
request's identity is stable regardless of how many others exist.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.tracegen import rng
from repro.core.tracegen.spec import trace_key
from repro.serving.sim.spec import ServingSpec

# serving-only counter sub-streams (tracegen's tags stop at 13)
TAG_SERVE_GAP = 21      # unit-rate Poisson gaps
TAG_SERVE_CLASS = 22    # chat-vs-RAG class uniform
TAG_SERVE_PROMPT = 23   # prompt-length draw
TAG_SERVE_DECODE = 24   # decode-length draw
TAG_SERVE_PREFIX = 25   # shared-prefix pick

_BISECT_ITERS = 64


def _unit_poisson(root: int, n: int) -> np.ndarray:
    """Event times of a unit-rate Poisson process (f64[n], increasing)."""
    u = rng.uniform(rng.stream_key(np.uint64(root), TAG_SERVE_GAP),
                    np.arange(n))
    return np.cumsum(-np.log1p(-u))


def _warp_bursty(t_unit: np.ndarray, spec: ServingSpec) -> np.ndarray:
    """Closed-form Λ⁻¹ for the square-wave (MMPP-style) rate."""
    hi = spec.rate * spec.burst_boost
    lo = spec.rate * (1.0 - spec.burst_duty * spec.burst_boost) \
        / (1.0 - spec.burst_duty)
    p = spec.burst_period
    t_on = spec.burst_duty * p
    mass_on = hi * t_on
    mass = spec.rate * p                      # Λ over one full period
    n_full = np.floor(t_unit / mass)
    rem = t_unit - n_full * mass
    in_burst = rem <= mass_on
    t_in = np.where(in_burst, rem / hi,
                    t_on + (rem - mass_on) / max(lo, 1e-300))
    return n_full * p + t_in


def _warp_diurnal(t_unit: np.ndarray, spec: ServingSpec) -> np.ndarray:
    """Vectorized bisection inverse of the sinusoidal integrated rate."""
    r, amp, p = spec.rate, spec.diurnal_amp, spec.diurnal_period
    w = 2.0 * np.pi / p

    def lam(t):
        return r * (t + amp / w * (1.0 - np.cos(w * t)))

    # Λ(t) is within r·amp·P/π of r·t, so bracket around t_unit / r
    c = r * amp * p / np.pi
    lo = np.maximum((t_unit - c) / r, 0.0)
    hi = (t_unit + c) / r + 1e-9
    for _ in range(_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        below = lam(mid) < t_unit
        lo = np.where(below, mid, lo)
        hi = np.where(below, hi, mid)
    return 0.5 * (lo + hi)


def arrival_times(spec: ServingSpec, seed: int = 0) -> np.ndarray:
    """Arrival times (engine steps, f64[n], non-decreasing) of the
    spec's open-loop process for one seed."""
    n = spec.n_requests
    if n == 0:
        return np.empty(0, np.float64)
    if spec.process == "closed":
        return np.zeros(n, np.float64)
    t_unit = _unit_poisson(trace_key(spec.name, seed), n)
    if spec.process == "poisson":
        return t_unit / spec.rate
    if spec.process == "bursty":
        return _warp_bursty(t_unit, spec)
    return _warp_diurnal(t_unit, spec)


def generate_serving(spec: ServingSpec, seed: int = 0
                     ) -> Dict[str, np.ndarray]:
    """The full request stream for one (spec, seed): ``arrival`` f64[n]
    plus i64[n] ``prompt_len``/``decode_len``/``prefix_id`` (-1 for
    RAG) / ``prefix_len``. The sequence's true class (chat = shared-hot,
    RAG = streaming-cold) is ``prefix_id >= 0`` — it is NOT declared to
    the runtime; the classifier must discover it (the oracle labeling
    mode is the exception, by design)."""
    n = spec.n_requests
    root = np.uint64(trace_key(spec.name, seed))
    idx = np.arange(n)
    chat = rng.uniform(rng.stream_key(root, TAG_SERVE_CLASS), idx) \
        < spec.chat_frac
    c_lo, c_hi = spec.chat_prompt
    r_lo, r_hi = spec.rag_prompt
    kp = rng.stream_key(root, TAG_SERVE_PROMPT)
    prompt = np.where(chat,
                      c_lo + rng.randint(kp, idx, max(c_hi - c_lo, 1)),
                      r_lo + rng.randint(kp, idx, max(r_hi - r_lo, 1)))
    d_lo, d_hi = spec.decode
    decode = d_lo + rng.randint(rng.stream_key(root, TAG_SERVE_DECODE),
                                idx, max(d_hi - d_lo, 1))
    prefix_id = np.where(chat,
                         rng.randint(rng.stream_key(root, TAG_SERVE_PREFIX),
                                     idx, max(spec.n_shared_prefixes, 1)),
                         -1)
    return {
        "arrival": arrival_times(spec, seed),
        "prompt_len": prompt.astype(np.int64),
        "decode_len": decode.astype(np.int64),
        "prefix_id": prefix_id.astype(np.int64),
        "prefix_len": np.where(chat, spec.shared_prefix_len, 0
                               ).astype(np.int64),
    }


def from_requests(requests: List) -> Dict[str, np.ndarray]:
    """Array form of a ``request.generate_requests`` list — the bridge
    the ServeEngine parity suite uses to feed both implementations the
    IDENTICAL closed-loop workload."""
    return {
        "arrival": np.asarray([r.arrival for r in requests], np.float64),
        "prompt_len": np.asarray([r.prompt_len for r in requests],
                                 np.int64),
        "decode_len": np.asarray([r.decode_len for r in requests],
                                 np.int64),
        "prefix_id": np.asarray(
            [-1 if r.shared_prefix_id is None else r.shared_prefix_id
             for r in requests], np.int64),
        "prefix_len": np.asarray([r.shared_prefix_len for r in requests],
                                 np.int64),
    }
