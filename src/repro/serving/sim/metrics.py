"""Serving metrics computed from state arrays.

Everything here is a float/int scalar so a metrics dict can ride the
declarative ``api`` result path (``ResultSet`` stacks scalars across
policies x seeds). Conventions:

  * ``latency``       finish - ARRIVAL (the open-loop, user-visible
                      number: queue wait included);
  * ``service_lat``   finish - enqueue (the closed-loop number the
                      ServeEngine snapshot calls "latency");
  * ``queue_wait``    enqueue - arrival, its own metric (satellite fix:
                      the engine used to fold this into nothing);
  * ``ttft``          first token - enqueue;
  * ``goodput``       tokens/step from COMPLETED requests only — tokens
                      poured into a request that never finishes within
                      the horizon don't count;
  * ``stall_steps``   includes in-flight requests, not just completed.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.serving.pool import MedicPoolManager
from repro.serving.sim.spec import ServingSpec
from repro.serving.sim.state import ServingState


def _pct(x: np.ndarray, q: float) -> float:
    return float(np.percentile(x, q)) if x.size else float("nan")


def _mean(x: np.ndarray) -> float:
    return float(np.mean(x)) if x.size else float("nan")


def summarize(state: ServingState, pool: MedicPoolManager,
              spec: ServingSpec) -> Dict[str, float]:
    done = state.finish_step >= 0
    admitted = state.enqueue_step >= 0
    first = state.first_token_step >= 0
    steps = max(state.step, 1)

    lat = state.finish_step[done] - state.arrival[done]
    service = (state.finish_step[done] -
               state.enqueue_step[done]).astype(np.float64)
    qwait = state.enqueue_step[admitted] - state.arrival[admitted]
    ttft = (state.first_token_step[first] -
            state.enqueue_step[first]).astype(np.float64)

    # censored tail: requests still in flight (or still queued) at the
    # horizon count at their latency-so-far lower bound, so a truncated
    # run cannot flatter a policy by completing only its easy requests
    seen = state.arrival <= state.step
    cens = np.where(state.finish_step >= 0,
                    state.finish_step - state.arrival,
                    state.step - state.arrival)[seen]

    acc = int(pool.accesses[:spec.max_slots].sum())
    hits = int(pool.hits[:spec.max_slots].sum())
    evictions = int(pool.evictions_by_type.sum())
    return {
        "completed": int(done.sum()),
        "admitted": int(admitted.sum()),
        "steps": int(state.step),
        "tokens_out": int(state.tokens_out),
        "throughput": state.tokens_out / steps,
        "goodput": float(state.decode_len[done].sum()) / steps,
        "mean_latency": _mean(lat),
        "p50_latency": _pct(lat, 50),
        "p99_latency": _pct(lat, 99),
        "p99_latency_censored": _pct(cens, 99),
        "mean_service_latency": _mean(service),
        "p99_service_latency": _pct(service, 99),
        "mean_queue_wait": _mean(qwait),
        "p99_queue_wait": _pct(qwait, 99),
        "mean_ttft": _mean(ttft),
        "p99_ttft": _pct(ttft, 99),
        "stall_steps": int(state.stall_steps.sum()),
        "fetches": int(pool.fetches),
        "bypassed_blocks": int(pool.bypassed_blocks),
        "evictions": evictions,
        "eviction_churn": evictions / steps,
        "hit_ratio": hits / max(acc, 1),
        "mean_concurrency": state.occ_steps / steps,
        "max_concurrency": int(state.max_concurrency),
        "mean_in_system": state.sys_steps / steps,
        "max_in_system": int(state.max_in_system),
    }
