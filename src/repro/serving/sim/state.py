"""Fixed-capacity array state for the serving simulator.

One ``ServingState`` holds the whole population: a request axis [R]
(arrival attributes + lifecycle timestamps) and a slot axis [S]
(occupancy, fetch-readiness, KV length) — the serving analogue of the
wavefront engine's SimState. Everything the step function touches is a
numpy array, so admission / residency / decode-commit operate on slot
populations, not Python request objects.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.serving.sim.spec import ServingSpec


@dataclasses.dataclass
class ServingState:
    """Mutable array state of one serving run."""
    # request axis [R] — arrival attributes (read-only after init)
    arrival: np.ndarray        # f64[R] arrival time (engine steps)
    prompt_len: np.ndarray     # i64[R] unique prompt tokens
    decode_len: np.ndarray     # i64[R] tokens to generate
    prefix_id: np.ndarray      # i64[R] shared-prefix id (-1 = RAG)
    prefix_len: np.ndarray     # i64[R] shared-prefix tokens (0 = RAG)
    # request axis [R] — lifecycle (engine-step stamps, -1 = not yet)
    enqueue_step: np.ndarray   # i64[R] admission step
    first_token_step: np.ndarray
    finish_step: np.ndarray
    generated: np.ndarray      # i64[R] tokens generated so far
    stall_steps: np.ndarray    # i64[R] steps spent fetch-stalled
    # slot axis [S]
    slot_req: np.ndarray       # i64[S] request in the slot (-1 = free)
    ready_at: np.ndarray       # f64[S] earliest step the slot may decode
    cache_len: np.ndarray      # i64[S] KV tokens held (prefill + decoded)
    fetch_pending: np.ndarray  # bool[S] stalled decode commits at ready_at
    # admission queue: request ids sorted by (arrival, id) — the stable
    # order ``ServeEngine.run``'s ``sorted(requests, key=arrival)`` uses
    order: np.ndarray          # i64[R]
    arr_sorted: np.ndarray     # f64[R] arrival[order] (admission cursor)
    qhead: int = 0
    # counters
    step: int = 0
    tokens_out: int = 0
    n_finished: int = 0
    # per-step samples (concurrency metrics / Little's-law checks)
    occ_steps: int = 0         # Σ occupied slots over steps
    sys_steps: int = 0         # Σ in-system (queued + occupied) requests
    max_concurrency: int = 0   # peak occupied slots
    max_in_system: int = 0

    @property
    def n_requests(self) -> int:
        return int(self.arrival.shape[0])

    @property
    def max_slots(self) -> int:
        return int(self.slot_req.shape[0])

    def pending(self) -> bool:
        """Anything left to do (mirrors the ServeEngine loop guard)?"""
        return self.qhead < self.n_requests or bool(
            (self.slot_req >= 0).any())


def init_state(reqs: Dict[str, np.ndarray], spec: ServingSpec
               ) -> ServingState:
    """Fresh state for one request stream (``arrivals.generate_serving``
    or ``arrivals.from_requests`` arrays)."""
    r = len(reqs["arrival"])
    order = np.argsort(reqs["arrival"], kind="stable").astype(np.int64)
    neg1 = lambda n: np.full(n, -1, np.int64)  # noqa: E731
    return ServingState(
        arrival=np.asarray(reqs["arrival"], np.float64),
        prompt_len=np.asarray(reqs["prompt_len"], np.int64),
        decode_len=np.asarray(reqs["decode_len"], np.int64),
        prefix_id=np.asarray(reqs["prefix_id"], np.int64),
        prefix_len=np.asarray(reqs["prefix_len"], np.int64),
        enqueue_step=neg1(r),
        first_token_step=neg1(r),
        finish_step=neg1(r),
        generated=np.zeros(r, np.int64),
        stall_steps=np.zeros(r, np.int64),
        slot_req=neg1(spec.max_slots),
        ready_at=np.zeros(spec.max_slots, np.float64),
        cache_len=np.zeros(spec.max_slots, np.int64),
        fetch_pending=np.zeros(spec.max_slots, bool),
        order=order,
        arr_sorted=np.asarray(reqs["arrival"], np.float64)[order],
    )
