"""Vectorized continuous-batching serving simulator (open-loop traffic).

The timing/accounting view of ``serving.engine.ServeEngine``: the same
admission / residency / decode-commit step semantics, advanced over
fixed-capacity arrays so thousands of concurrent requests are one step's
work, driven by deterministic counter-RNG arrival processes instead of a
fixed closed-loop request list. ``ServeEngine`` remains the real-data-
path reference; the parity suite pins the two on closed-loop workloads.
"""
from repro.serving.sim.arrivals import (arrival_times, from_requests,
                                        generate_serving)
from repro.serving.sim.metrics import summarize
from repro.serving.sim.spec import SERVING_SPECS, ServingSpec
from repro.serving.sim.state import ServingState, init_state
from repro.serving.sim.step import POOL_BACKENDS, simulate_serving

__all__ = [
    "ServingSpec", "SERVING_SPECS", "ServingState", "init_state",
    "arrival_times", "generate_serving", "from_requests",
    "simulate_serving", "POOL_BACKENDS", "summarize",
]
