"""Batched admission -> residency -> decode-commit serving step.

One ``sim_step`` advances EVERY slot of a ``ServingState`` through the
exact per-step semantics of ``ServeEngine.run`` — same admission order
(free slots in index order paired with the arrival-sorted queue head),
same stall accounting (waiting on an in-flight fetch AND newly stalled
both count), same residency transaction per block key, same decode
commit (``cache_len`` grows only on active slots) — but expressed over
arrays. The pool transaction itself goes through
``MedicPoolManager.access_batch`` (one call covering all active slots,
``pool_backend="fast"``) or the sequential per-key reference loop
(``"ref"``); a differential suite pins fast == ref bitwise, and a
closed-loop parity suite pins ref == ServeEngine per request.

The simulator has no data path (no model, no KV payloads) — it is the
timing/accounting view of the engine, which is what makes thousands of
concurrent slots per step affordable.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import warp_types as WT
from repro.policy import Policy
from repro.serving.pool import MedicPoolManager
from repro.serving.sim import metrics as sim_metrics
from repro.serving.sim.spec import ServingSpec
from repro.serving.sim.state import ServingState, init_state

POOL_BACKENDS = ("auto", "ref", "fast")


def _block_keys_arrays(state: ServingState, spec: ServingSpec,
                       slots: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Residency keys for this step's decode on ``slots`` (ascending).

    Returns ``(owner, kslot, kblk)`` flat arrays in slot-major, block-
    ascending order — the exact key sequence ``ServeEngine.run`` emits:
    the first ``shared_prefix_len // block_tokens`` blocks of a chat
    sequence live under the prefix's pseudo-slot ``max_slots + pid``.
    """
    bs = spec.block_tokens
    rid = state.slot_req[slots]
    length = np.minimum(state.cache_len[slots] + 1, spec.max_len)
    nblk = -(-length // bs)
    owner = np.repeat(slots, nblk)
    seg_start = np.concatenate(([0], np.cumsum(nblk)[:-1]))
    kblk = np.arange(owner.size, dtype=np.int64) - np.repeat(seg_start, nblk)
    pid = state.prefix_id[rid]
    nshared = np.where(pid >= 0, state.prefix_len[rid] // bs, 0)
    kslot = np.where(kblk < np.repeat(nshared, nblk),
                     spec.max_slots + np.repeat(pid, nblk), owner)
    return owner, kslot, kblk


def _admit(state: ServingState, spec: ServingSpec,
           pool: MedicPoolManager, now: float):
    """Admit queued requests into free slots — free slots in index order
    each take the arrival-sorted queue head, exactly the ServeEngine
    scan. Prefill is accounting-only: reset the slot, (oracle mode) pin
    the true label, then ``insert_prefill`` every prompt block."""
    n_arr = int(np.searchsorted(state.arr_sorted, now, side="right"))
    avail = n_arr - state.qhead
    if avail <= 0:
        return
    free = np.nonzero(state.slot_req < 0)[0]
    take = min(avail, free.size)
    if take <= 0:
        return
    oracle = pool.label_mode == "oracle"
    for j in range(take):
        slot = int(free[j])
        rid = int(state.order[state.qhead + j])
        state.slot_req[slot] = rid
        state.enqueue_step[rid] = state.step
        state.ready_at[slot] = now
        state.fetch_pending[slot] = False
        pool.reset_slot(slot)
        if oracle:
            # ground truth the classifier only estimates: chat sequences
            # (shared-hot prefix) are MOSTLY_HIT, RAG streams MOSTLY_MISS
            chat = state.prefix_id[rid] >= 0
            pool.set_oracle_type(
                slot, WT.MOSTLY_HIT if chat else WT.MOSTLY_MISS)
        plen = int(state.prefix_len[rid] + state.prompt_len[rid])
        state.cache_len[slot] = plen
        stype = int(pool.seq_type[slot])
        bs = spec.block_tokens
        nshared = int(state.prefix_len[rid]) // bs \
            if state.prefix_id[rid] >= 0 else 0
        pid = int(state.prefix_id[rid])
        for i in range(-(-plen // bs)):
            key = (spec.max_slots + pid, i) if i < nshared else (slot, i)
            pool.insert_prefill(key, stype)
    state.qhead += take


def _access_ref(pool: MedicPoolManager, owner: np.ndarray,
                kslot: np.ndarray, kblk: np.ndarray, now: float
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Sequential per-key reference transaction — the literal
    ``ServeEngine.run`` call pattern, one ``pool.access`` per block."""
    cut = np.nonzero(np.diff(owner))[0] + 1
    starts = np.concatenate(([0], cut))
    ends = np.concatenate((cut, [owner.size]))
    seg_owner = owner[starts].copy()
    ready = np.full(len(seg_owner), float(now))
    for si in range(len(seg_owner)):
        o = int(seg_owner[si])
        t = float(now)
        for q in range(starts[si], ends[si]):
            tq, _ = pool.access(o, [int(kblk[q])], now,
                                resident_key=(int(kslot[q]), int(kblk[q])))
            t = max(t, tq)
        ready[si] = t
    return seg_owner, ready


def sim_step(state: ServingState, spec: ServingSpec,
             pool: MedicPoolManager, fast: bool) -> None:
    """One engine step: admission, residency, decode-commit."""
    now = float(state.step)
    _admit(state, spec, pool, now)

    occupied = state.slot_req >= 0
    occ = int(occupied.sum())
    n_arr = int(np.searchsorted(state.arr_sorted, now, side="right"))
    state.occ_steps += occ
    state.sys_steps += n_arr - state.n_finished
    state.max_concurrency = max(state.max_concurrency, occ)
    state.max_in_system = max(state.max_in_system,
                              n_arr - state.n_finished)

    # waiting on an in-flight fetch: stalled, no residency transaction
    waiting = occupied & (state.ready_at > now)
    if waiting.any():
        wr = state.slot_req[waiting]
        state.stall_steps[wr] += 1

    eligible = occupied & (state.ready_at <= now)
    # a stalled slot's fetches landed: its delayed decode commits with
    # the streamed data — no second residency transaction (re-accessing
    # would re-miss bypassed blocks forever and livelock the miss class)
    landing = np.nonzero(eligible & state.fetch_pending)[0]
    transact = np.nonzero(eligible & ~state.fetch_pending)[0]
    state.fetch_pending[landing] = False
    if landing.size == 0 and transact.size == 0:
        state.step += 1
        return
    if transact.size:
        owner, kslot, kblk = _block_keys_arrays(state, spec, transact)
        if fast:
            seg_owner, ready = pool.access_batch(owner, kslot, kblk, now)
        else:
            seg_owner, ready = _access_ref(pool, owner, kslot, kblk, now)
        # every eligible slot holds >= 1 block, so segments == transact
        t_ready = np.asarray(ready)
        stalled = t_ready > now
        if stalled.any():
            ss = seg_owner[stalled]
            state.ready_at[ss] = t_ready[stalled]
            state.fetch_pending[ss] = True
            state.stall_steps[state.slot_req[ss]] += 1
        decoded = seg_owner[~stalled]
    else:
        decoded = np.empty(0, np.int64)
    active = np.sort(np.concatenate((landing, decoded)))
    if active.size:
        ar = state.slot_req[active]
        state.generated[ar] += 1
        state.tokens_out += int(active.size)
        newly = state.first_token_step[ar] < 0
        state.first_token_step[ar[newly]] = state.step
        state.cache_len[active] += 1
        fin = state.generated[ar] >= state.decode_len[ar]
        if fin.any():
            fr = ar[fin]
            state.finish_step[fr] = state.step
            state.slot_req[active[fin]] = -1
            state.n_finished += int(fin.sum())
    state.step += 1


def simulate_serving(reqs: Dict[str, np.ndarray], spec: ServingSpec,
                     policy: Optional[Policy] = None,
                     pool_backend: str = "auto",
                     max_steps: Optional[int] = None
                     ) -> Dict[str, object]:
    """Run one serving scenario to completion (or ``max_steps``).

    ``reqs`` is a request-stream dict (``arrivals.generate_serving`` /
    ``from_requests``); ``policy`` a unified-engine ``Policy`` preset
    (None -> the pool's ``medic`` default); ``pool_backend`` selects the
    vectorized (``fast``) or sequential-reference (``ref``) pool
    transaction (``auto`` -> fast). Returns ``{"metrics": scalars,
    "request_arrays": per-request lifecycle arrays, "pool": counters}``.
    """
    if pool_backend not in POOL_BACKENDS:
        raise ValueError(f"unknown pool_backend {pool_backend!r}; "
                         f"choose from {POOL_BACKENDS}")
    fast = pool_backend != "ref"
    state = init_state(reqs, spec)
    pool = MedicPoolManager(spec.pool_config(),
                            spec.max_slots + spec.n_pseudo_slots,
                            policy=policy)
    limit = int(max_steps if max_steps is not None else spec.max_steps)
    while state.pending() and state.step < limit:
        sim_step(state, spec, pool, fast)
    return {
        "metrics": sim_metrics.summarize(state, pool, spec),
        "request_arrays": {
            "enqueue_step": state.enqueue_step.copy(),
            "first_token_step": state.first_token_step.copy(),
            "finish_step": state.finish_step.copy(),
            "generated": state.generated.copy(),
            "stall_steps": state.stall_steps.copy(),
        },
        "pool": {
            "fetches": pool.fetches,
            "bypassed_blocks": pool.bypassed_blocks,
            "hits": pool.hits.copy(),
            "accesses": pool.accesses.copy(),
            "seq_type": pool.seq_type.copy(),
            "evictions_by_type": pool.evictions_by_type.copy(),
            "resident_blocks": int((pool._slot >= 0).sum()),
        },
    }
