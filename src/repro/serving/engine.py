"""Continuous-batching serving engine with MeDiC-managed KV residency.

The engine runs a *real* (reduced-config) decoder LM: admission -> prefill
-> batched decode steps, with the KV cache of every slot physically managed
at block granularity by ``MedicPoolManager``:

  * on eviction a block's K/V payload is copied to a host-side store and
    ZEROED in the device cache;
  * on fetch it is restored before the decode step runs;
  * sequences whose fetches have not completed (two-queue transfer model)
    skip decode steps (the warp-stall analogue).

Because the data path is real, a residency-accounting bug corrupts logits —
tests exploit this by comparing a tight-budget run's outputs against an
unconstrained run (they must be bit-identical).

Shared-prefix blocks are accounting-shared across sequences (pseudo-slots);
their payloads are duplicated per-slot and not offloaded (timing realism,
data-path simplification — see DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import build_model
from repro.serving.pool import MedicPoolManager, PoolConfig
from repro.serving.request import Request, ServeWorkload, generate_requests


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8
    max_len: int = 512
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig,
                 pool_cfg: PoolConfig):
        assert cfg.family in ("dense",), "engine demo targets dense LMs"
        self.cfg = cfg
        self.ecfg = ecfg
        self.model = build_model(cfg)
        self.params = self.model.init_params(jax.random.PRNGKey(ecfg.seed))
        self.shape = ShapeConfig("serve", ecfg.max_len, ecfg.max_slots,
                                 "decode")
        self.cache = self.model.init_cache(ecfg.max_slots, self.shape)
        self.bs = pool_cfg.block_tokens
        # pseudo-slots for shared prefixes sit after the real slots
        self.pool = MedicPoolManager(pool_cfg, ecfg.max_slots + 8,
                                     on_evict=self._offload)
        self.host_store: Dict[tuple, np.ndarray] = {}
        self.slots: List[Optional[Request]] = [None] * ecfg.max_slots
        self._decode = jax.jit(self.model.decode)
        self._prefill = jax.jit(self.model.prefill)
        self.rng = np.random.default_rng(ecfg.seed)

    # -- block data path ------------------------------------------------------

    def _kv_leaves(self):
        sc = self.cache["stack"]["scan"]
        key = next(iter(sc))
        return sc[key]

    def _offload(self, key):
        slot, idx = key
        if slot >= self.ecfg.max_slots:
            return  # shared pseudo-slot: accounting only
        kv = self._kv_leaves()
        lo = idx * self.bs
        k = np.asarray(kv["k"][:, slot, lo:lo + self.bs])
        v = np.asarray(kv["v"][:, slot, lo:lo + self.bs])
        self.host_store[key] = np.stack([k, v])
        zer = jnp.zeros_like(kv["k"][:, slot, lo:lo + self.bs])
        kv["k"] = kv["k"].at[:, slot, lo:lo + self.bs].set(zer)
        kv["v"] = kv["v"].at[:, slot, lo:lo + self.bs].set(zer)

    def _restore(self, key):
        slot, idx = key
        if slot >= self.ecfg.max_slots:
            return
        data = self.host_store.get(key)
        if data is None:
            return  # never offloaded (still physically present)
        kv = self._kv_leaves()
        lo = idx * self.bs
        kv["k"] = kv["k"].at[:, slot, lo:lo + self.bs].set(
            jnp.asarray(data[0]))
        kv["v"] = kv["v"].at[:, slot, lo:lo + self.bs].set(
            jnp.asarray(data[1]))

    # -- request lifecycle ----------------------------------------------------

    def _prompt_tokens(self, req: Request) -> np.ndarray:
        toks = []
        if req.shared_prefix_id is not None:
            prng = np.random.default_rng(1000 + req.shared_prefix_id)
            toks.append(prng.integers(1, self.cfg.vocab_size,
                                      req.shared_prefix_len))
        prng = np.random.default_rng(2000 + req.rid)
        toks.append(prng.integers(1, self.cfg.vocab_size, req.prompt_len))
        return np.concatenate(toks).astype(np.int32)

    def _block_keys(self, req: Request, length: int) -> List[tuple]:
        """Residency keys for the first `length` tokens of the sequence.
        Shared-prefix blocks map to the prefix's pseudo-slot."""
        keys = []
        nshared = req.shared_prefix_len // self.bs if req.shared_prefix_id is not None else 0
        nblocks = -(-length // self.bs)
        for i in range(nblocks):
            if i < nshared:
                keys.append((self.ecfg.max_slots + req.shared_prefix_id, i))
            else:
                keys.append((req.slot, i))
        return keys

    def _admit(self, req: Request, slot: int, step: int):
        req.slot = slot
        req.enqueue_step = step
        self.slots[slot] = req
        self.pool.reset_slot(slot)
        for key in list(self.host_store):
            if key[0] == slot:
                del self.host_store[key]
        toks = self._prompt_tokens(req)
        # single-sequence prefill merged into the batch cache at `slot`
        one = ShapeConfig("p", len(toks), 1, "prefill")
        c1 = self.model.init_cache(1, one)
        logits, c1 = self._prefill(self.params,
                                   {"tokens": jnp.asarray(toks)[None]}, c1)
        self._merge_slot_cache(c1, slot, len(toks))
        # prefilled blocks enter the pool under the insertion policy,
        # without fetch cost (they were just produced on-device)
        stype = int(self.pool.seq_type[slot])
        for key in self._block_keys(req, len(toks)):
            self.pool.insert_prefill(key, stype)

    def _merge_slot_cache(self, c1, slot: int, length: int):
        """Write a 1-sequence prefill cache into batch position `slot`."""
        w = self.cache["kv_pos"].shape[1]
        kv = self._kv_leaves()
        src = c1["stack"]["scan"][next(iter(c1["stack"]["scan"]))]
        s = min(length, w)
        kv["k"] = kv["k"].at[:, slot, :s].set(src["k"][:, 0, :s])
        kv["v"] = kv["v"].at[:, slot, :s].set(src["v"][:, 0, :s])
        self.cache["len"] = self.cache["len"].at[slot].set(length)
        kvp = np.full((w,), -1, np.int32)
        for p in range(max(0, length - w), length):
            kvp[p % w] = p
        self.cache["kv_pos"] = self.cache["kv_pos"].at[slot].set(
            jnp.asarray(kvp))

    # -- main loop --------------------------------------------------------------

    def run(self, requests: List[Request], max_steps: int = 2000):
        pending = sorted(requests, key=lambda r: r.arrival)
        done: List[Request] = []
        ready_at = np.zeros(self.ecfg.max_slots)
        # a stalled slot's fetches are in flight: when they land, the
        # delayed decode commits with the streamed data (already restored
        # at access time) instead of re-running the residency transaction
        # — re-accessing would re-miss bypassed blocks forever and
        # livelock every mostly-miss sequence behind its own streaming
        fetch_pending = np.zeros(self.ecfg.max_slots, bool)
        tokens_out = 0
        step = 0
        while (pending or any(self.slots)) and step < max_steps:
            now = float(step)
            # admissions
            for i, cur in enumerate(self.slots):
                if cur is None and pending and pending[0].arrival <= now:
                    self._admit(pending.pop(0), i, step)
                    ready_at[i] = now
                    fetch_pending[i] = False
            # residency transactions for the upcoming decode
            active = np.zeros(self.ecfg.max_slots, bool)
            for i, req in enumerate(self.slots):
                if req is None or ready_at[i] > now:
                    if req is not None:
                        req.stall_steps += 1
                    continue
                if fetch_pending[i]:
                    fetch_pending[i] = False
                    active[i] = True
                    continue
                length = int(self.cache["len"][i]) + 1
                keys = self._block_keys(req, min(length, self.ecfg.max_len))
                t_ready = now
                for key in keys:
                    t, fetched = self.pool.access(i, [key[1]], now,
                                                  resident_key=key)
                    # restore data for any fetched (non-resident) block;
                    # bypassed (streamed) blocks are re-offloaded after the
                    # step below
                    if fetched:
                        self._restore(key)
                    t_ready = max(t_ready, t)
                if t_ready > now:
                    ready_at[i] = t_ready
                    fetch_pending[i] = True
                    req.stall_steps += 1
                else:
                    active[i] = True
            if active.any():
                toks = np.zeros((self.ecfg.max_slots, 1), np.int32)
                logits, new_cache = self._decode(self.params,
                                                 jnp.asarray(toks),
                                                 self.cache)
                # commit only active slots
                self.cache = _select_cache(new_cache, self.cache,
                                           jnp.asarray(active))
                for i, req in enumerate(self.slots):
                    if req is None or not active[i]:
                        continue
                    req.generated += 1
                    tokens_out += 1
                    if req.first_token_step < 0:
                        req.first_token_step = step
                    if req.generated >= req.decode_len:
                        req.finish_step = step
                        done.append(req)
                        self.slots[i] = None
                # streamed (bypassed) blocks leave the device again
                for i, req in enumerate(self.slots):
                    if req is None or not active[i]:
                        continue
                    length = int(self.cache["len"][i])
                    for key in self._block_keys(req, min(length, self.ecfg.max_len)):
                        if not self.pool.is_resident(key) and key in self.host_store:
                            self._offload(key)
            step += 1

        snap = self.pool.snapshot()
        in_flight = [r for r in self.slots if r is not None]
        lat = [r.finish_step - r.enqueue_step for r in done]
        ttft = [r.first_token_step - r.enqueue_step for r in done
                if r.first_token_step >= 0]
        # queue wait is its own metric (latency above starts at admission,
        # so it would otherwise vanish); admitted = done + still in flight
        qwait = [r.enqueue_step - r.arrival for r in done + in_flight]
        snap.update({
            "steps": step,
            "completed": len(done),
            "tokens_out": tokens_out,
            "throughput": tokens_out / max(step, 1),
            "mean_latency": float(np.mean(lat)) if lat else float("nan"),
            "p99_latency": float(np.percentile(lat, 99)) if lat else float("nan"),
            "mean_ttft": float(np.mean(ttft)) if ttft else float("nan"),
            "mean_queue_wait": float(np.mean(qwait)) if qwait else float("nan"),
            "p99_queue_wait": float(np.percentile(qwait, 99)) if qwait else float("nan"),
            # in-flight requests stall too — dropping them undercounted
            # exactly the runs where stalls matter (truncated, congested)
            "stall_steps": sum(r.stall_steps for r in done + in_flight),
        })
        return snap


def _select_cache(new, old, active_mask):
    """Commit cache updates only for active batch slots."""

    def sel(n, o):
        if n.shape == ():
            return n
        # find the batch axis: stack leaves are [G, B, ...], top-level
        # leaves are [B, ...]
        if n.ndim >= 2 and n.shape[1] == active_mask.shape[0] and \
                n.shape[0] != active_mask.shape[0]:
            m = active_mask.reshape((1, -1) + (1,) * (n.ndim - 2))
        elif n.shape[0] == active_mask.shape[0]:
            m = active_mask.reshape((-1,) + (1,) * (n.ndim - 1))
        else:
            return n
        return jnp.where(m, n, o)

    return jax.tree.map(sel, new, old)


def run_ab(cfg: ModelConfig, wl: ServeWorkload, pool_cfg: PoolConfig,
           ecfg: EngineConfig = EngineConfig(), seed: int = 0):
    """A/B the MeDiC pool manager against LRU on the same workload."""
    out = {}
    for policy in ("lru", "medic"):
        pc = dataclasses.replace(pool_cfg, policy=policy)
        eng = ServeEngine(cfg, ecfg, pc)
        reqs = generate_requests(wl, seed=seed)
        out[policy] = eng.run(reqs)
    return out
