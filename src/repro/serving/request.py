"""Serving request model + workload generator.

Heterogeneity mirrors the paper's warp populations: chat-style requests
share hot prefix blocks (high pool utility — the mostly/all-hit class)
while long-unique-context (RAG-style) requests stream cold blocks through
the pool (the mostly/all-miss class). Which class a *sequence* lands in is
NOT declared to the runtime — the MeDiC classifier must discover it from
observed residency hit ratios.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    decode_len: int
    shared_prefix_id: Optional[int]   # id of a shared system-prompt prefix
    shared_prefix_len: int
    arrival: float                    # engine-step time of arrival
    # runtime state
    slot: int = -1
    generated: int = 0
    stall_steps: int = 0
    enqueue_step: int = 0
    first_token_step: int = -1
    finish_step: int = -1


@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    name: str = "chat_rag_mix"
    n_requests: int = 64
    chat_frac: float = 0.6           # share of requests with hot prefixes
    n_shared_prefixes: int = 2
    shared_prefix_len: int = 48      # tokens (multiple of block size ideally)
    chat_prompt: tuple = (16, 48)    # unique prompt tokens, uniform range
    rag_prompt: tuple = (192, 384)   # long unique contexts
    decode: tuple = (32, 96)
    arrival_rate: float = 2.0        # requests per engine step


def generate_requests(wl: ServeWorkload, seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    t = 0.0
    for rid in range(wl.n_requests):
        t += rng.exponential(1.0 / wl.arrival_rate)
        if rng.random() < wl.chat_frac:
            reqs.append(Request(
                rid=rid,
                prompt_len=int(rng.integers(*wl.chat_prompt)),
                decode_len=int(rng.integers(*wl.decode)),
                shared_prefix_id=int(rng.integers(0, wl.n_shared_prefixes)),
                shared_prefix_len=wl.shared_prefix_len,
                arrival=t,
            ))
        else:
            reqs.append(Request(
                rid=rid,
                prompt_len=int(rng.integers(*wl.rag_prompt)),
                decode_len=int(rng.integers(*wl.decode)),
                shared_prefix_id=None,
                shared_prefix_len=0,
                arrival=t,
            ))
    return reqs
