"""Reference (dict-based) pool manager — kept as the behavioural oracle.

This is the original per-block Python-dict implementation of the MeDiC
KV-block-pool control plane. The production ``serving.pool.MedicPoolManager``
re-implements it on fixed-capacity numpy arrays driven by the shared
``repro.policy`` decision tables; ``tests/test_policy_engine.py`` replays
recorded access traces through both and asserts their ``snapshot()``s
match exactly. Do not "optimize" this file — its value is fidelity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import warp_types as WT
from repro.serving.pool import PoolConfig


class DictPoolManager:
    """Residency + policy control plane (dict-based reference)."""

    def __init__(self, cfg: PoolConfig, max_seqs: int, on_evict=None):
        self.cfg = cfg
        self.max_seqs = max_seqs
        self.on_evict = on_evict or (lambda key: None)
        # per-(seq-slot, block-index) residency; block key = (slot, idx);
        # shared prefixes get their own pseudo-slots at the end
        self.resident: Dict[Tuple[int, int], int] = {}   # key -> rrip rank
        self.owner_type: Dict[Tuple[int, int], int] = {}
        # classifier counters per slot (incl. pseudo-slots)
        self.hits = np.zeros(max_seqs, np.int64)
        self.accesses = np.zeros(max_seqs, np.int64)
        self.win_hits = np.zeros(max_seqs, np.int64)
        self.win_acc = np.zeros(max_seqs, np.int64)
        self.seq_type = np.full(max_seqs, WT.BALANCED, np.int64)
        self.ratio = np.full(max_seqs, 0.5, np.float64)
        # two-queue transfer engine
        self.hp_free = 0.0
        self.lp_free = 0.0
        # metrics
        self.fetches = 0
        self.fetch_bytes_blocks = 0
        self.qdelays: List[float] = []
        self.evictions_by_type = np.zeros(WT.NUM_TYPES, np.int64)
        self.bypassed_blocks = 0

    # -- classification (①) -------------------------------------------------

    def _observe(self, slot: int, hit: bool):
        self.hits[slot] += hit
        self.accesses[slot] += 1
        self.win_hits[slot] += hit
        self.win_acc[slot] += 1
        if self.win_acc[slot] >= self.cfg.sampling_interval:
            r = self.win_hits[slot] / max(self.win_acc[slot], 1)
            self.ratio[slot] = r
            self.seq_type[slot] = int(np.asarray(WT.classify(
                np.float32(r), np.int32(self.win_acc[slot]),
                mostly_hit_threshold=self.cfg.mostly_hit_threshold,
                mostly_miss_threshold=self.cfg.mostly_miss_threshold,
                min_samples=1)))
            self.win_hits[slot] = 0
            self.win_acc[slot] = 0

    def reset_slot(self, slot: int):
        """New sequence admitted into the slot: drop its blocks + counters."""
        for key in [k for k in self.resident if k[0] == slot]:
            del self.resident[key]
            self.owner_type.pop(key, None)
        self.hits[slot] = self.accesses[slot] = 0
        self.win_hits[slot] = self.win_acc[slot] = 0
        self.seq_type[slot] = WT.BALANCED
        self.ratio[slot] = 0.5

    # -- the per-step residency transaction ----------------------------------

    def access(self, slot: int, blocks: List[int], now: float,
               resident_key: Optional[Tuple[int, int]] = None
               ) -> Tuple[float, List[int]]:
        """A decode step for sequence `slot` needs `blocks`. Returns
        (ready_time, fetched_block_list). Updates residency per policy.
        `resident_key` overrides the residency key (shared-prefix blocks
        live under a pseudo-slot while counting toward `slot`'s ratio)."""
        cfg = self.cfg
        medic = cfg.policy == "medic"
        stype = int(self.seq_type[slot])
        ready = now
        fetched = []
        for blk in blocks:
            key = resident_key if resident_key is not None else (slot, blk)
            hit = key in self.resident
            self._observe(slot, hit)
            if hit:
                # promotion: hit blocks move to rank 0 (MRU analogue)
                self.resident[key] = 0
                continue
            # ---- miss -> fetch through the two-queue scheduler (④) -------
            self.fetches += 1
            self.fetch_bytes_blocks += 1
            fetched.append(blk)
            hp = medic and WT.is_priority_type(np.int32(stype))
            if hp:
                t0 = max(self.hp_free, now)
                self.hp_free = t0 + cfg.fetch_occupancy
            else:
                t0 = max(self.lp_free, self.hp_free, now)
                self.lp_free = t0 + cfg.fetch_occupancy
            self.qdelays.append(t0 - now)
            ready = max(ready, t0 + cfg.fetch_latency)
            # ---- insertion / bypass (②③) ---------------------------------
            bypass = medic and WT.is_bypass_type(np.int32(stype))
            if bypass:
                self.bypassed_blocks += 1
                continue  # streamed: not retained
            rank = (int(np.asarray(WT.insertion_rank(
                np.int32(stype), cfg.rrip_max - 1))) if medic else 0)
            self._insert(key, rank, stype)
        return ready, fetched

    def _insert(self, key, rank: int, stype: int):
        cfg = self.cfg
        while len(self.resident) >= cfg.budget_blocks:
            victim = max(self.resident.items(), key=lambda kv: kv[1])[0]
            vt = self.owner_type.pop(victim, WT.BALANCED)
            self.evictions_by_type[vt] += 1
            del self.resident[victim]
            self.on_evict(victim)
        # age everyone mildly on insertion pressure (RRIP-flavoured)
        if len(self.resident) >= cfg.budget_blocks - 1:
            for k in self.resident:
                self.resident[k] = min(self.resident[k] + 1, cfg.rrip_max)
        self.resident[key] = rank
        self.owner_type[key] = stype

    def insert_prefill(self, key, stype: int):
        """Blocks produced on-device at prefill: no fetch cost, but they
        enter the pool under the insertion/bypass policy."""
        medic = self.cfg.policy == "medic"
        if medic and WT.is_bypass_type(np.int32(stype)):
            self.bypassed_blocks += 1
            self.on_evict(key)   # streamed immediately (not retained)
            return
        rank = (int(np.asarray(WT.insertion_rank(
            np.int32(stype), self.cfg.rrip_max - 1))) if medic else 0)
        self._insert(key, rank, stype)

    def is_resident(self, key) -> bool:
        return key in self.resident

    # -- metrics --------------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        ratios = np.where(self.accesses > 0,
                          self.hits / np.maximum(self.accesses, 1), np.nan)
        return {
            "fetches": self.fetches,
            "bypassed_blocks": self.bypassed_blocks,
            "mean_qdelay": float(np.mean(self.qdelays)) if self.qdelays else 0.0,
            "p99_qdelay": float(np.percentile(self.qdelays, 99)) if self.qdelays else 0.0,
            "qdelays": np.asarray(self.qdelays),
            "seq_hit_ratio": ratios,
            "seq_type": self.seq_type.copy(),
            "resident_blocks": len(self.resident),
            "evictions_by_type": self.evictions_by_type.copy(),
        }
