"""AdamW + schedules + gradient accumulation + train-step builder.

Distributed-optimization features:
  * optimizer moments in bf16 (``moment_dtype``) — halves optimizer HBM,
    the lever that lets grok-1-314b train on a 256-chip pod (see
    EXPERIMENTS.md §Dry-run);
  * optional int8 gradient compression with error feedback
    (``grad_compression="int8"``): a ``shard_map``-based compressed
    all-reduce for the slow cross-pod axis plus an in-step quantizer with
    an error-feedback accumulator;
  * gradient accumulation via ``lax.scan`` over microbatches;
  * ZeRO-3: optimizer state inherits the parameters' FSDP sharding (it is
    created with the same logical axes), so XLA shards it over ``data``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig

F32 = jnp.float32


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------

def lr_schedule(cfg: OptimizerConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def init_opt_state(params, cfg: OptimizerConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.grad_compression == "int8":
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return state


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def quantize_int8(x, err):
    """int8 quantize with error feedback. Returns (deq, new_err)."""
    xf = x.astype(F32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    return deq.astype(x.dtype), xf - deq


def compressed_psum(x, axis_name: str):
    """int8 all-reduce building block for shard_map sections: quantize the
    local shard, sum int32 partials, dequantize with a max-scale exchange.
    Comm volume: 1 byte/elt + one f32 scale vs 4 bytes/elt."""
    scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(x)), 1e-12),
                         axis_name) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(F32) * scale


def adamw_update(grads, state, params, cfg: OptimizerConfig):
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    new_err = state.get("err")

    def upd(g, m, v, p, e=None):
        g = g.astype(F32) * clip
        if e is not None:
            g, e_new = quantize_int8(g, e)
            g = g.astype(F32)
        else:
            e_new = None
        m_new = cfg.b1 * m.astype(F32) + (1 - cfg.b1) * g
        v_new = cfg.b2 * v.astype(F32) + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** count)
        vhat = v_new / (1 - cfg.b2 ** count)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        p_new = (p.astype(F32) - lr * step).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype), e_new

    mdt = state["m"]
    if cfg.grad_compression == "int8":
        out = jax.tree.map(upd, grads, state["m"], state["v"], params,
                           state["err"])
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda o: o[3], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": new_m, "v": new_v, "count": count, "err": new_err}
    else:
        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": new_m, "v": new_v, "count": count}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# train step builder
# ---------------------------------------------------------------------------

def make_train_step(model, cfg: OptimizerConfig, microbatches: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, gradients are accumulated with a lax.scan over
    equal splits of the batch (the global batch stays the deliverable
    shape; accumulation shrinks live activation memory).
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            mb = jax.tree.map(split, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)

            def acc_step(carry, mbatch):
                g_acc, l_acc = carry
                (loss, metrics), grads = grad_fn(params, mbatch)
                g_acc = jax.tree.map(lambda a, g: a + g.astype(F32) /
                                     microbatches, g_acc, grads)
                return (g_acc, l_acc + loss / microbatches), metrics

            (grads, loss), metrics = jax.lax.scan(
                acc_step, (zero, jnp.zeros((), F32)), mb)
            metrics = jax.tree.map(lambda x: x[-1], metrics)
        new_params, new_state, opt_metrics = adamw_update(
            grads, opt_state, params, cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return step
