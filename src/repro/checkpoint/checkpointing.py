"""Sharded, atomic, async checkpointing with auto-resume and re-shard.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json  (+ .tmp staging)

Properties needed at 1000-node scale, implemented here single-process with
the same structure:
  * atomic publish — writes go to ``step_N.tmp`` and are renamed only after
    fsync, so a killed writer never corrupts the latest checkpoint;
  * async save — a background thread serializes device arrays that were
    snapshotted (host-copied) at save() call time, so the train loop
    resumes immediately;
  * mesh-agnostic restore — arrays are stored unsharded-logical and pushed
    onto the target sharding at load (``device_put`` with NamedSharding),
    so a checkpoint taken on one mesh restores on any other (elastic
    re-scale path; exercised in tests with different device counts);
  * retention — keep the last ``keep`` checkpoints, delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Flatten to numpy; bfloat16 is stored as a uint16 view (npz-safe)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out, dtypes = {}, {}
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.name == "bfloat16":
            arr = arr.view(np.uint16)
        out[key] = arr
    return out, dtypes


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten(template, flat: Dict[str, np.ndarray],
               dtypes: Dict[str, str]):
    import ml_dtypes
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves:
        key = SEP.join(_path_str(p) for p in path)
        arr = flat[key]
        if dtypes.get(key) == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False):
        # snapshot to host synchronously (cheap vs serialization)
        flat, dtypes = _flatten(tree)
        extra = dict(extra or {})
        self.wait()

        def _write():
            tmp = os.path.join(self.directory, f"step_{step}.tmp")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "extra": extra,
                           "dtypes": dtypes, "keys": sorted(flat)}, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- load -----------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> Tuple[Any, Dict]:
        path = os.path.join(self.directory, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        tree = _unflatten(template, flat, manifest.get("dtypes", {}))
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(lambda a: jax.numpy.asarray(a), tree)
        return tree, manifest["extra"]

    def restore_latest(self, template: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, template, shardings)
        return step, tree, extra
