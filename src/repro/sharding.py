"""Logical-axis sharding rules (MaxText-style) for MeDiC-JAX.

Every parameter / activation dimension is tagged with a *logical* axis name;
``build_rules`` maps logical axes onto mesh axes for the current mesh, and
``spec_for`` resolves a tuple of logical names into a ``PartitionSpec``,
dropping any assignment that does not divide the concrete dimension (so the
same model code runs on the (16,16) production mesh, the (2,16,16) multi-pod
mesh, and a 1-device CPU test mesh).

Parallelism carried by each mesh axis:
  pod    -- pure data parallelism across pods (only gradient all-reduce
            crosses the slow inter-pod links)
  data   -- data parallelism + FSDP (ZeRO-3 parameter/optimizer sharding
            over the ``embed`` logical axis)
  model  -- tensor parallelism (heads / mlp / vocab), expert parallelism
            (``expert``), sequence parallelism of the residual stream
            (``seq_sp``) and of the decode KV cache (``kv_seq``)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Order matters: earlier rules win a mesh axis; later rules that would reuse
# an already-taken mesh axis on the same tensor are dropped.
DEFAULT_LOGICAL_RULES: Tuple[Tuple[str, MeshAxes], ...] = (
    ("batch", ("pod", "data")),
    ("capacity", ("pod", "data")),
    ("expert", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("vocab", "model"),
    ("lru", "model"),
    ("seq_sp", "model"),      # sequence parallelism (residual stream)
    ("kv_seq", "model"),      # decode KV-cache length sharding
    ("embed", "data"),        # FSDP / ZeRO-3 on parameters
    ("embed_act", None),      # activations keep embed replicated
    ("layers", None),
    ("seq", None),
    ("head_dim", None),
    ("image", None),
    ("enc_seq", None),
)


def build_rules(mesh: Mesh,
                overrides: Sequence[Tuple[str, MeshAxes]] = ()) -> Dict[str, MeshAxes]:
    """Instantiate the logical->mesh mapping for a concrete mesh.

    Mesh axes that the mesh does not have (e.g. ``pod`` on the single-pod
    mesh) are removed from every rule.
    """
    present = set(mesh.axis_names)
    rules: Dict[str, MeshAxes] = {}
    merged = list(DEFAULT_LOGICAL_RULES) + list(overrides)
    for name, axes in merged:
        if axes is None:
            rules[name] = None
            continue
        if isinstance(axes, str):
            axes = (axes,)
        kept = tuple(a for a in axes if a in present)
        rules[name] = kept if kept else None
    return rules


def _mesh_axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for(logical: Sequence[Optional[str]],
             shape: Sequence[int],
             mesh: Mesh,
             rules: Dict[str, MeshAxes]) -> P:
    """Resolve logical axis names -> PartitionSpec with divisibility fallback.

    A logical axis is left unsharded when (a) it has no rule, (b) its mesh
    axes are already used by an earlier dimension of this tensor, or (c) the
    dimension size is not divisible by the mesh-axis product. Mesh axes of
    size 1 carry no parallelism: they resolve to ``None`` WITHOUT being
    consumed, so a (1, N) mesh hands its only real axis to the first
    dimension that can actually use it (a size-1 assignment used to be
    kept — ``dim % 1 == 0`` — and marked used, starving later dimensions
    of the same tensor on meshes where that axis is larger).
    """
    assert len(logical) == len(shape), (logical, shape)
    used: set = set()
    out = []
    for name, dim in zip(logical, shape):
        axes = rules.get(name) if name is not None else None
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes
                     if a not in used and mesh.shape[a] > 1)
        if not axes:
            out.append(None)
            continue
        if dim % _mesh_axis_size(mesh, axes) != 0:
            # partial fallback: try a prefix of the axes tuple
            while axes and (dim % _mesh_axis_size(mesh, axes) != 0):
                axes = axes[:-1]
            if not axes:
                out.append(None)
                continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def sharding_for(logical, shape, mesh, rules) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical, shape, mesh, rules))


def tree_shardings(logical_tree, shape_tree, mesh, rules):
    """Zip a logical-axes tree with a ShapeDtypeStruct tree -> NamedShardings."""
    return jax.tree.map(
        lambda lg, sd: sharding_for(lg.axes, sd.shape, mesh, rules),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, Logical),
    )


def tree_specs(logical_tree, shape_tree, mesh, rules):
    return jax.tree.map(
        lambda lg, sd: spec_for(lg.axes, sd.shape, mesh, rules),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, Logical),
    )


class Logical:
    """A leaf marker carrying logical axis names for one array."""
    __slots__ = ("axes",)

    def __init__(self, *axes: Optional[str]):
        self.axes = tuple(axes)

    def __repr__(self):
        return f"Logical{self.axes}"

    def __eq__(self, other):
        return isinstance(other, Logical) and self.axes == other.axes

    def __hash__(self):
        return hash(self.axes)


# ---------------------------------------------------------------------------
# Activation-sharding context: model code calls shard_act(x, *logical_axes)
# and the constraint resolves against the ambient (mesh, rules); it is a
# no-op outside a sharding context (pure-CPU unit tests).
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Dict[str, MeshAxes]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: Optional[Dict[str, MeshAxes]] = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules if rules is not None else build_rules(mesh)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> Optional[Dict[str, MeshAxes]]:
    return _CTX.rules


def shard_act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op
    without an ambient sharding context). The trivial-mesh check uses
    ``mesh.size`` (total device count): ``len(mesh.devices)`` only
    measures the FIRST dimension of the 2-D device ndarray, so a (1, N)
    mesh looked single-device and every constraint silently no-opped."""
    if _CTX.mesh is None or _CTX.mesh.size <= 1:
        return x
    spec = spec_for(logical, x.shape, _CTX.mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


# ---------------------------------------------------------------------------
# Sweep-axis placement: the plan compiler (repro.api) and simulate_sweep
# shard the stacked policy / seed / warp axes of a sweep over a device
# mesh. These helpers implement the shared resolution contract: a size-1
# mesh axis never shards, and an axis product that does not divide the
# dimension falls back to replication (never an error) — so the same
# Experiment runs unchanged on a 1-device box and an 8-device mesh.
# ---------------------------------------------------------------------------

def norm_axes(axes: MeshAxes) -> Optional[Tuple[str, ...]]:
    """None | "name" | ("a", "b") -> None | tuple of names."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def resolve_axes(mesh: Optional[Mesh], axes: MeshAxes,
                 dim: int) -> MeshAxes:
    """The mesh axes that actually shard a dimension of size ``dim``:
    size-1 mesh axes are dropped, and if the remaining axis product does
    not divide ``dim`` the whole assignment resolves to ``None``
    (replication fallback — sharding must never change which problems
    are expressible)."""
    if mesh is None:
        return None
    axes = norm_axes(axes)
    if axes is None:
        return None
    axes = tuple(a for a in axes if mesh.shape[a] > 1)
    if not axes or dim % _mesh_axis_size(mesh, axes) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def leading_sharding(mesh: Mesh, axes: MeshAxes,
                     rank: int) -> NamedSharding:
    """NamedSharding placing (pre-resolved) ``axes`` on dim 0 of a
    rank-``rank`` array, everything else replicated. ``axes=None`` is
    full replication (still a committed placement on ``mesh``)."""
    if axes is None or rank == 0:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(axes, *([None] * (rank - 1))))


def put_leading(x, mesh: Optional[Mesh], axes: MeshAxes):
    """``device_put`` an array with its leading dim sharded over
    ``axes`` (already resolved; ``None`` replicates). No-op without a
    mesh."""
    if mesh is None:
        return x
    import jax.numpy as jnp
    x = jnp.asarray(x)
    return jax.device_put(x, leading_sharding(mesh, axes, x.ndim))


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """make_mesh pinned to Auto axis types (portable across jax versions)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def single_device_mesh() -> Mesh:
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))
