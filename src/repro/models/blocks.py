"""BlockDefs for every model family.

Block apply signature: (cfg, params, x, aux, cache) -> (x, new_cache, aux_loss)

``aux`` carries scan-invariant context:
  static:  "mode" in {"train","prefill","decode"}
  arrays:  "q_pos" [B,S]   positions of current tokens
           "kv_pos" [B,W]  positions held in the self-attn cache (-1 invalid)
           "write_slot" [B] decode write index into the cache ring
           "enc_out" [B,Se,D], "enc_pos" [Se]      (whisper)
           "img" [B,Ti,D], "img_pos" [Ti]          (vlm)

Caches are per-layer slices handed in by the stack scan.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import recurrent as REC
from repro.models import xlstm as XL
from repro.models.stack import BlockDef
from repro.sharding import Logical, shard_act

F32 = jnp.float32


# ---------------------------------------------------------------------------
# shared attention plumbing
# ---------------------------------------------------------------------------

def _self_attention(cfg, p, x, aux, cache, *, window=None, use_rope=True,
                    causal=True):
    """Returns (attn_out, new_cache). Handles train/prefill/decode."""
    mode = aux["mode"]
    q, k, v = L.attn_project_qkv(cfg, p, x, aux["q_pos"], use_rope=use_rope)
    softcap = None  # per-layer attn softcap unused; final-logit cap in model

    if mode == "train" or cache is None:
        o = L.attention_train(q, k, v, aux["q_pos"], aux["q_pos"],
                              window=window, causal=causal, softcap=softcap)
        return L.attn_out(p, o), None

    if mode == "prefill":
        o = L.attention_prefill(q, k, v, aux["q_pos"], aux["q_pos"],
                                window=window, causal=causal, softcap=softcap)
        w = cache["k"].shape[1]
        s = k.shape[1]
        if w >= s:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        else:
            # ring buffer: keep the last w tokens at slot = pos % w
            slots = aux["q_pos"][:, s - w:] % w                  # [B,w]
            ck = _scatter_ring(cache["k"], k[:, s - w:], slots)
            cv = _scatter_ring(cache["v"], v[:, s - w:], slots)
        return L.attn_out(p, o), {"k": ck, "v": cv}

    # decode: write new kv at write_slot, attend over cache
    slot = aux["write_slot"]                                     # [B]
    ck = _scatter_ring(cache["k"], k, slot[:, None])
    cv = _scatter_ring(cache["v"], v, slot[:, None])
    o = L.attention_decode(q, ck.astype(q.dtype), cv.astype(q.dtype),
                           aux["q_pos"], aux["kv_pos"],
                           window=window, softcap=softcap)
    return L.attn_out(p, o), {"k": ck, "v": cv}


def _scatter_ring(cache, kv_new, slots):
    """cache [B,W,kv,hd]; kv_new [B,S,kv,hd]; slots [B,S] -> updated cache."""

    def upd(c_b, kv_b, s_b):
        return c_b.at[s_b].set(kv_b.astype(c_b.dtype))

    return jax.vmap(upd)(cache, kv_new, slots)


def _cross_attention(cfg, p, x, mem, mem_pos, cache, *, fresh: bool):
    """Cross attention; KV from `mem` when fresh (train/prefill) else cached."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if not fresh and cache is not None:
        k, v = cache["xk"].astype(q.dtype), cache["xv"].astype(q.dtype)
        new_cache = cache
    else:
        k = jnp.einsum("bsd,dhk->bshk", mem, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", mem, p["wv"])
        new_cache = None
        if cache is not None:
            new_cache = {"xk": k.astype(cache["xk"].dtype),
                         "xv": v.astype(cache["xv"].dtype)}
    qpos = jnp.zeros((x.shape[0], x.shape[1]), jnp.int32)
    kpos = jnp.zeros((x.shape[0], k.shape[1]), jnp.int32)
    o = L.attention_full(q, k, v, qpos, kpos, causal=False)
    return L.attn_out(p, o), new_cache


def _kv_cache_init(cfg, batch, w, dtype):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    c = {"k": jnp.zeros((batch, w, kv, hd), dtype),
         "v": jnp.zeros((batch, w, kv, hd), dtype)}
    lg = {"k": Logical("batch", "kv_seq", "kv_heads", None),
          "v": Logical("batch", "kv_seq", "kv_heads", None)}
    return c, lg


# ---------------------------------------------------------------------------
# dense / moe transformer layer
# ---------------------------------------------------------------------------

def _norm_params(key, cfg):
    return jnp.zeros((cfg.d_model,), F32), Logical("embed")


def dense_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    ap, alg = L.attn_params(k1, cfg)
    mp, mlg = L.mlp_params(k2, cfg, gated=True)
    n1, n1lg = _norm_params(key, cfg)
    n2, n2lg = _norm_params(key, cfg)
    return ({"norm1": n1, "attn": ap, "norm2": n2, "mlp": mp},
            {"norm1": n1lg, "attn": alg, "norm2": n2lg, "mlp": mlg})


def dense_layer_apply(cfg, p, x, aux, cache):
    # TP-boundary outputs are tagged so the remat policy can SAVE them
    # (sequence-sharded, so cheap) instead of re-running the fwd TP
    # all-reduce/all-gather pair during backward recompute (§Perf q1).
    x = shard_act(x, "batch", "seq_sp", None)
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    a, new_cache = _self_attention(cfg, p["attn"], h, aux, cache,
                                   window=cfg.sliding_window)
    a = checkpoint_name(shard_act(a, "batch", "seq_sp", None), "tp_out")
    x = x + a
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    y = checkpoint_name(
        shard_act(L.mlp_apply(cfg, p["mlp"], h), "batch", "seq_sp", None),
        "tp_out")
    x = x + y
    return x, new_cache, jnp.zeros((), F32)


def dense_layer_cache(cfg, batch, shape_cfg):
    w = shape_cfg.seq_len
    if cfg.sliding_window is not None:
        w = min(w, cfg.sliding_window)
    return _kv_cache_init(cfg, batch, w, jnp.dtype(cfg.dtype))


def moe_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    ap, alg = L.attn_params(k1, cfg)
    mp, mlg = MOE.moe_params(k2, cfg)
    n1, n1lg = _norm_params(key, cfg)
    n2, n2lg = _norm_params(key, cfg)
    return ({"norm1": n1, "attn": ap, "norm2": n2, "moe": mp},
            {"norm1": n1lg, "attn": alg, "norm2": n2lg, "moe": mlg})


def moe_layer_apply(cfg, p, x, aux, cache):
    x = shard_act(x, "batch", "seq_sp", None)
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    a, new_cache = _self_attention(cfg, p["attn"], h, aux, cache,
                                   window=cfg.sliding_window)
    x = x + a
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    y, aux_loss = MOE.moe_apply(cfg, p["moe"], h)
    x = x + y
    x = shard_act(x, "batch", "seq_sp", None)
    return x, new_cache, aux_loss


# ---------------------------------------------------------------------------
# RecurrentGemma blocks
# ---------------------------------------------------------------------------

def rec_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    rp, rlg = REC.rglru_params(k1, cfg)
    mp, mlg = L.mlp_params(k2, cfg)
    n1, _ = _norm_params(key, cfg)
    n2, _ = _norm_params(key, cfg)
    return ({"norm1": n1, "rec": rp, "norm2": n2, "mlp": mp},
            {"norm1": Logical("embed"), "rec": rlg,
             "norm2": Logical("embed"), "mlp": mlg})


def rec_block_apply(cfg, p, x, aux, cache):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    y, new_cache = REC.rglru_apply(cfg, p["rec"], h, cache)
    x = x + y
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + L.mlp_apply(cfg, p["mlp"], h)
    return x, new_cache, jnp.zeros((), F32)


def rec_block_cache(cfg, batch, shape_cfg):
    return REC.rglru_cache(cfg, batch)


def local_attn_init(key, cfg):
    return dense_layer_init(key, cfg)


def local_attn_apply(cfg, p, x, aux, cache):
    x = shard_act(x, "batch", "seq_sp", None)
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    a, new_cache = _self_attention(cfg, p["attn"], h, aux, cache,
                                   window=cfg.local_window)
    x = x + a
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + L.mlp_apply(cfg, p["mlp"], h)
    return x, new_cache, jnp.zeros((), F32)


def local_attn_cache(cfg, batch, shape_cfg):
    w = min(shape_cfg.seq_len, cfg.local_window)
    return _kv_cache_init(cfg, batch, w, jnp.dtype(cfg.dtype))


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def mlstm_block_init(key, cfg):
    p, lg = XL.mlstm_params(key, cfg)
    n, nlg = _norm_params(key, cfg)
    return {"norm": n, "mlstm": p}, {"norm": nlg, "mlstm": lg}


def mlstm_block_apply(cfg, p, x, aux, cache):
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    y, new_cache = XL.mlstm_apply(cfg, p["mlstm"], h, cache)
    return x + y, new_cache, jnp.zeros((), F32)


def mlstm_block_cache(cfg, batch, shape_cfg):
    return XL.mlstm_cache(cfg, batch)


def slstm_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p, lg = XL.slstm_params(k1, cfg)
    mp, mlg = L.mlp_params(k2, cfg, d_ff=max(cfg.d_ff, 4 * cfg.d_model // 3))
    n1, _ = _norm_params(key, cfg)
    n2, _ = _norm_params(key, cfg)
    return ({"norm1": n1, "slstm": p, "norm2": n2, "mlp": mp},
            {"norm1": Logical("embed"), "slstm": lg,
             "norm2": Logical("embed"), "mlp": mlg})


def slstm_block_apply(cfg, p, x, aux, cache):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    y, new_cache = XL.slstm_apply(cfg, p["slstm"], h, cache)
    x = x + y
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + L.mlp_apply(cfg, p["mlp"], h)
    return x, new_cache, jnp.zeros((), F32)


def slstm_block_cache(cfg, batch, shape_cfg):
    return XL.slstm_cache(cfg, batch)


# ---------------------------------------------------------------------------
# Whisper blocks (encoder bidirectional; decoder self + cross)
# ---------------------------------------------------------------------------

def enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    ap, alg = L.attn_params(k1, cfg)
    mp, mlg = L.mlp_params(k2, cfg, gated=False)
    n1, _ = _norm_params(key, cfg)
    n2, _ = _norm_params(key, cfg)
    return ({"norm1": n1, "attn": ap, "norm2": n2, "mlp": mp},
            {"norm1": Logical("embed"), "attn": alg,
             "norm2": Logical("embed"), "mlp": mlg})


def enc_layer_apply(cfg, p, x, aux, cache):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    q, k, v = L.attn_project_qkv(cfg, p["attn"], h, aux["q_pos"], use_rope=False)
    o = L.attention_full(q, k, v, aux["q_pos"], aux["q_pos"], causal=False)
    x = x + L.attn_out(p["attn"], o)
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + L.mlp_apply(cfg, p["mlp"], h)
    return x, None, jnp.zeros((), F32)


def dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    ap, alg = L.attn_params(k1, cfg)
    xp, xlg = L.attn_params(k2, cfg, cross=True)
    mp, mlg = L.mlp_params(k3, cfg, gated=False)
    n1, _ = _norm_params(key, cfg)
    n2, _ = _norm_params(key, cfg)
    n3, _ = _norm_params(key, cfg)
    return ({"norm1": n1, "attn": ap, "norm2": n2, "xattn": xp,
             "norm3": n3, "mlp": mp},
            {"norm1": Logical("embed"), "attn": alg,
             "norm2": Logical("embed"), "xattn": xlg,
             "norm3": Logical("embed"), "mlp": mlg})


def dec_layer_apply(cfg, p, x, aux, cache):
    self_cache = None
    cross_cache = None
    if cache is not None:
        self_cache = {"k": cache["k"], "v": cache["v"]}
        cross_cache = {"xk": cache["xk"], "xv": cache["xv"]}
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    a, new_self = _self_attention(cfg, p["attn"], h, aux, self_cache,
                                  use_rope=True)
    x = x + a
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    a, new_cross = _cross_attention(cfg, p["xattn"], h, aux["enc_out"],
                                    aux["enc_pos"], cross_cache,
                                    fresh=aux["mode"] != "decode")
    x = x + a
    h = L.rms_norm(x, p["norm3"], cfg.norm_eps)
    x = x + L.mlp_apply(cfg, p["mlp"], h)
    new_cache = None
    if cache is not None:
        new_cache = dict(new_self)
        new_cache.update(new_cross)
    return x, new_cache, jnp.zeros((), F32)


def dec_layer_cache(cfg, batch, shape_cfg):
    dtype = jnp.dtype(cfg.dtype)
    c, lg = _kv_cache_init(cfg, batch, shape_cfg.seq_len, dtype)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    c["xk"] = jnp.zeros((batch, cfg.encoder_seq_len, kv, hd), dtype)
    c["xv"] = jnp.zeros((batch, cfg.encoder_seq_len, kv, hd), dtype)
    lg["xk"] = Logical("batch", "enc_seq", "kv_heads", None)
    lg["xv"] = Logical("batch", "enc_seq", "kv_heads", None)
    return c, lg


# ---------------------------------------------------------------------------
# VLM cross block (Llama-3.2-Vision style gated cross-attention layer)
# ---------------------------------------------------------------------------

def vlm_cross_init(key, cfg):
    k1, k2 = jax.random.split(key)
    xp, xlg = L.attn_params(k1, cfg, cross=True)
    mp, mlg = L.mlp_params(k2, cfg)
    n1, _ = _norm_params(key, cfg)
    n2, _ = _norm_params(key, cfg)
    return ({"norm1": n1, "xattn": xp, "gate_attn": jnp.zeros((), F32),
             "norm2": n2, "mlp": mp, "gate_mlp": jnp.zeros((), F32)},
            {"norm1": Logical("embed"), "xattn": xlg, "gate_attn": Logical(),
             "norm2": Logical("embed"), "mlp": mlg, "gate_mlp": Logical()})


def vlm_cross_apply(cfg, p, x, aux, cache):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    a, new_cache = _cross_attention(cfg, p["xattn"], h, aux["img"],
                                    aux["img_pos"], cache,
                                    fresh=aux["mode"] != "decode")
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * L.mlp_apply(cfg, p["mlp"], h)
    return x, new_cache, jnp.zeros((), F32)


def vlm_cross_cache(cfg, batch, shape_cfg):
    dtype = jnp.dtype(cfg.dtype)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    c = {"xk": jnp.zeros((batch, cfg.num_image_tokens, kv, hd), dtype),
         "xv": jnp.zeros((batch, cfg.num_image_tokens, kv, hd), dtype)}
    lg = {"xk": Logical("batch", "kv_seq", "kv_heads", None),
          "xv": Logical("batch", "kv_seq", "kv_heads", None)}
    return c, lg


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BLOCKS = {
    "layer": BlockDef("layer", dense_layer_init, dense_layer_apply,
                      dense_layer_cache),
    "moe_layer": BlockDef("moe_layer", moe_layer_init, moe_layer_apply,
                          dense_layer_cache),
    "rec": BlockDef("rec", rec_block_init, rec_block_apply, rec_block_cache),
    "attn": BlockDef("attn", local_attn_init, local_attn_apply,
                     local_attn_cache),
    "mlstm": BlockDef("mlstm", mlstm_block_init, mlstm_block_apply,
                      mlstm_block_cache),
    "slstm": BlockDef("slstm", slstm_block_init, slstm_block_apply,
                      slstm_block_cache),
    "enc": BlockDef("enc", enc_layer_init, enc_layer_apply, None),
    "dec": BlockDef("dec", dec_layer_init, dec_layer_apply, dec_layer_cache),
    "self": BlockDef("self", dense_layer_init, dense_layer_apply,
                     dense_layer_cache),
    "cross": BlockDef("cross", vlm_cross_init, vlm_cross_apply,
                      vlm_cross_cache),
}
