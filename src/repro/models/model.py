"""Unified Model API for all 10 assigned architectures.

``build_model(cfg)`` returns a ``Model`` with:
  init_params(rng)             -> params pytree (bf16 weights)
  logical_params()             -> parallel tree of sharding.Logical leaves
  loss(params, batch)          -> (scalar loss, metrics)          [train]
  prefill(params, batch, cache)-> (last-pos logits, cache)        [serve]
  decode(params, tokens, cache)-> (logits, cache)                 [serve]
  init_cache(batch, shape_cfg) -> cache pytree (+ logical tree)
  input_specs(shape_cfg)       -> dict of ShapeDtypeStruct stand-ins
  cache_specs(shape_cfg)       -> cache as ShapeDtypeStruct tree

The cache pytree always contains:
  "stack":  per-block-kind stacked caches (KV rings / recurrent states)
  "len":    [B] int32 tokens generated so far
  "kv_pos": [B, W] int32 positions held in self-attn cache slots (-1 empty)
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import blocks as B
from repro.models import layers as L
from repro.models.stack import StackDef, apply_stack, init_stack, init_stack_cache
from repro.sharding import Logical, shard_act

F32 = jnp.float32


# ---------------------------------------------------------------------------
# stack construction per family
# ---------------------------------------------------------------------------

def _stackdef(cfg: ModelConfig) -> StackDef:
    fam = cfg.family
    if fam == "dense":
        return StackDef(("layer",), cfg.num_layers, B.BLOCKS)
    if fam == "moe":
        return StackDef(("moe_layer",), cfg.num_layers, B.BLOCKS)
    if fam == "hybrid":
        pattern = cfg.block_pattern or ("rec", "rec", "attn")
        n = cfg.num_layers // len(pattern)
        tail = tuple(pattern[: cfg.num_layers - n * len(pattern)])
        return StackDef(pattern, n, B.BLOCKS, tail=tail)
    if fam == "ssm":
        pattern = cfg.block_pattern or ("mlstm", "slstm")
        n = cfg.num_layers // len(pattern)
        tail = tuple(pattern[: cfg.num_layers - n * len(pattern)])
        return StackDef(pattern, n, B.BLOCKS, tail=tail)
    if fam == "vlm":
        k = cfg.cross_attn_every
        pattern = ("self",) * (k - 1) + ("cross",)
        assert cfg.num_layers % k == 0
        return StackDef(pattern, cfg.num_layers // k, B.BLOCKS)
    if fam == "encdec":
        return StackDef(("dec",), cfg.num_layers, B.BLOCKS)
    raise ValueError(fam)


def _enc_stackdef(cfg: ModelConfig) -> StackDef:
    return StackDef(("enc",), cfg.num_encoder_layers, B.BLOCKS)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stack = _stackdef(cfg)
        self.enc_stack = _enc_stackdef(cfg) if cfg.family == "encdec" else None

    # -- params ------------------------------------------------------------

    def init_params(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k_emb, k_stack, k_enc, k_head = jax.random.split(rng, 4)
        params: Dict[str, Any] = {
            "embed": L.embed_init(k_emb, (cfg.padded_vocab, cfg.d_model), dtype),
            "final_norm": jnp.zeros((cfg.d_model,), F32),
        }
        params["stack"], self._stack_lg = init_stack(k_stack, cfg, self.stack)
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(
                k_head, (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype)
        if self.enc_stack is not None:
            params["enc_stack"], self._enc_lg = init_stack(k_enc, cfg,
                                                           self.enc_stack)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), F32)
        return params

    def logical_params(self):
        cfg = self.cfg
        # make sure the cached stack logical trees exist
        if not hasattr(self, "_stack_lg"):
            jax.eval_shape(self.init_params, jax.random.PRNGKey(0))
        lg: Dict[str, Any] = {
            "embed": Logical("vocab", "embed"),
            "final_norm": Logical("embed"),
            "stack": self._stack_lg,
        }
        if not cfg.tie_embeddings:
            lg["lm_head"] = Logical("embed", "vocab")
        if self.enc_stack is not None:
            lg["enc_stack"] = self._enc_lg
            lg["enc_norm"] = Logical("embed")
        return lg

    # -- shared forward ----------------------------------------------------

    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        return shard_act(x, "batch", None, None)

    def _head(self, params, x):
        x = L.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", x, w).astype(F32)
        if self.cfg.logit_softcap:
            c = self.cfg.logit_softcap
            logits = c * jnp.tanh(logits / c)
        return shard_act(logits, "batch", None, "vocab")

    def _encode(self, params, frames):
        """Whisper encoder over precomputed (stubbed) frame embeddings."""
        cfg = self.cfg
        se = frames.shape[1]
        pos = jnp.arange(se, dtype=jnp.int32)[None, :]
        x = frames + _sinusoidal(se, cfg.d_model, frames.dtype)
        aux = {"mode": "train", "q_pos": jnp.broadcast_to(pos, frames.shape[:2])}
        x, _, _ = apply_stack(cfg, self.enc_stack, params["enc_stack"], x, aux)
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _aux_for(self, params, batch, mode, cache=None, tokens=None):
        cfg = self.cfg
        aux: Dict[str, Any] = {"mode": mode}
        if mode in ("train", "prefill"):
            t = tokens if tokens is not None else batch["tokens"]
            bsz, s = t.shape
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
            aux["q_pos"] = pos
        else:
            bsz = tokens.shape[0]
            aux["q_pos"] = cache["len"][:, None]
            aux["kv_pos"] = cache["kv_pos"]
            w = cache["kv_pos"].shape[1]
            aux["write_slot"] = cache["len"] % w
        if cfg.family == "encdec":
            enc_out = (self._encode(params, batch["frames"])
                       if mode != "decode" else cache["enc_out"])
            aux["enc_out"] = enc_out
            aux["enc_pos"] = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        if cfg.family == "vlm":
            img = batch["image_embeds"] if mode != "decode" else None
            if img is None:
                img = jnp.zeros((bsz, 1, cfg.d_model), jnp.dtype(cfg.dtype))
            aux["img"] = img
            aux["img_pos"] = jnp.arange(img.shape[1], dtype=jnp.int32)
        return aux

    # -- train -------------------------------------------------------------

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        tokens = batch["tokens"]
        aux = self._aux_for(params, batch, "train")
        x = self._embed(params, tokens)
        x, _, aux_loss = apply_stack(cfg, self.stack, params["stack"], x, aux)
        logits = self._head(params, x)
        targets = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask = jnp.concatenate(
            [jnp.ones_like(tokens[:, 1:], F32), jnp.zeros_like(tokens[:, :1], F32)],
            axis=1)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt_logit = jnp.take_along_axis(logits, targets[..., None],
                                        axis=-1)[..., 0]
        nll = (lse - tgt_logit) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll) / denom
        zloss = 1e-4 * jnp.sum((lse * mask) ** 2) / denom
        total = ce + zloss + cfg.router_aux_coef * aux_loss
        return total, {"loss": total, "ce": ce, "aux_loss": aux_loss,
                       "zloss": zloss}

    # -- serve -------------------------------------------------------------

    def prefill(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        aux = self._aux_for(params, batch, "prefill")
        x = self._embed(params, tokens)
        x, new_stack, _ = apply_stack(cfg, self.stack, params["stack"], x, aux,
                                      cache=cache["stack"], remat=False)
        logits = self._head(params, x[:, -1:])
        s = tokens.shape[1]
        w = cache["kv_pos"].shape[1]
        kv_pos = _ring_positions(s, w)[None]
        new_cache = {
            "stack": new_stack,
            "len": jnp.full_like(cache["len"], s),
            "kv_pos": jnp.broadcast_to(kv_pos, cache["kv_pos"].shape),
        }
        if cfg.family == "encdec":
            new_cache["enc_out"] = aux["enc_out"]
        return logits[:, 0], new_cache

    def decode(self, params, tokens, cache):
        """tokens: [B,1]. Returns (logits [B,V], new cache)."""
        cfg = self.cfg
        aux = self._aux_for(params, None, "decode", cache=cache, tokens=tokens)
        # the new token's kv_pos lands at write_slot
        w = cache["kv_pos"].shape[1]
        slot = aux["write_slot"]
        kv_pos = jax.vmap(lambda kp, s, l: kp.at[s].set(l))(
            cache["kv_pos"], slot, cache["len"])
        aux["kv_pos"] = kv_pos
        x = self._embed(params, tokens)
        x, new_stack, _ = apply_stack(cfg, self.stack, params["stack"], x, aux,
                                      cache=cache["stack"], remat=False)
        logits = self._head(params, x)
        new_cache = dict(cache)
        new_cache.update({
            "stack": new_stack,
            "len": cache["len"] + 1,
            "kv_pos": kv_pos,
        })
        return logits[:, 0], new_cache

    # -- caches / specs ------------------------------------------------------

    def _window(self, shape_cfg: ShapeConfig) -> int:
        cfg = self.cfg
        w = shape_cfg.seq_len
        if cfg.family == "hybrid":
            w = min(w, cfg.local_window)
        elif cfg.sliding_window is not None:
            w = min(w, cfg.sliding_window)
        elif cfg.family == "ssm":
            w = 1  # no attention cache; keep a stub ring of 1
        return w

    def init_cache(self, batch: int, shape_cfg: ShapeConfig,
                   filled: bool = False):
        cfg = self.cfg
        stack_cache, _ = init_stack_cache(cfg, self.stack, batch, shape_cfg)
        w = self._window(shape_cfg)
        if filled:
            # decode dry-run: cache holds seq_len-1 tokens already
            ln = jnp.full((batch,), shape_cfg.seq_len - 1, jnp.int32)
            kvp = _ring_positions(shape_cfg.seq_len - 1, w)[None]
        else:
            ln = jnp.zeros((batch,), jnp.int32)
            kvp = jnp.full((1, w), -1, jnp.int32)
        cache = {"stack": stack_cache, "len": ln,
                 "kv_pos": jnp.broadcast_to(kvp, (batch, w))}
        if cfg.family == "encdec":
            cache["enc_out"] = jnp.zeros(
                (batch, cfg.encoder_seq_len, cfg.d_model), jnp.dtype(cfg.dtype))
        return cache

    def cache_logical(self, batch: int, shape_cfg: ShapeConfig):
        cfg = self.cfg
        # build the logical tree WITHOUT allocating the (potentially
        # hundreds-of-GB) cache arrays: trace abstractly, capture the
        # logical side-channel
        holder = {}

        def build():
            c, lg = init_stack_cache(cfg, self.stack, batch, shape_cfg)
            holder["lg"] = lg
            return c

        jax.eval_shape(build)
        out = {"stack": holder["lg"], "len": Logical("batch"),
               "kv_pos": Logical("batch", "kv_seq")}
        if cfg.family == "encdec":
            out["enc_out"] = Logical("batch", "enc_seq", None)
        return out

    def input_specs(self, shape_cfg: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        bsz = shape_cfg.global_batch
        dtype = jnp.dtype(cfg.dtype)
        if shape_cfg.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((bsz, shape_cfg.seq_len),
                                                    jnp.int32)}
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((bsz, 1), jnp.int32)}
        if cfg.family == "encdec" and shape_cfg.kind != "decode":
            specs["frames"] = jax.ShapeDtypeStruct(
                (bsz, cfg.encoder_seq_len, cfg.d_model), dtype)
        if cfg.family == "vlm" and shape_cfg.kind != "decode":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (bsz, cfg.num_image_tokens, cfg.d_model), dtype)
        return specs

    def cache_specs(self, shape_cfg: ShapeConfig):
        return jax.eval_shape(
            lambda: self.init_cache(shape_cfg.global_batch, shape_cfg,
                                    filled=True))

    def batch_logical(self, shape_cfg: ShapeConfig):
        lg = {"tokens": Logical("batch", None)}
        if self.cfg.family == "encdec" and shape_cfg.kind != "decode":
            lg["frames"] = Logical("batch", "enc_seq", None)
        if self.cfg.family == "vlm" and shape_cfg.kind != "decode":
            lg["image_embeds"] = Logical("batch", None, None)
        return lg


def _ring_positions(filled_len: int, w: int) -> jnp.ndarray:
    """Positions stored in each ring slot after `filled_len` writes."""
    slots = np.full((w,), -1, np.int32)
    for p in range(max(0, filled_len - w), filled_len):
        slots[p % w] = p
    return jnp.asarray(slots)


def _sinusoidal(s: int, d: int, dtype):
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None]
    ang = pos / np.power(10000.0, 2 * i / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)[None]


# ---------------------------------------------------------------------------
# factory + analytics
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    total = 0
    moe_frac = (cfg.num_experts_per_tok / cfg.num_experts
                if cfg.num_experts else 1.0)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        keys = "/".join(str(k) for k in path)
        if active_only and cfg.num_experts and (
                "w_gate" in keys or "w_up" in keys or "w_down" in keys) and \
                "moe" in keys:
            n = int(n * moe_frac)
        total += n
    return total
