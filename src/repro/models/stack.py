"""Generic scanned block-stack machinery.

Every model family is expressed as a repeated *group pattern* of typed
blocks, e.g. dense = ("layer",) x L, RecurrentGemma = ("rec","rec","attn") x 8
(+ a tail), xLSTM = ("mlstm","slstm") x 6, Llama-Vision =
("self","self","self","self","cross_self") x 8.

Parameters for each position in the pattern are stacked along a leading
``n_groups`` axis and the whole stack executes as one ``jax.lax.scan`` over
groups — this keeps multi-hundred-layer dry-run compiles at ~1 s on the
512-device mesh and is the deployment structure (scan + remat) we cost.

A block kind is described by a ``BlockDef``:
  init(key, cfg)                      -> (params, logical)
  apply(cfg, p, x, aux, cache_slice)  -> (x, new_cache_slice)
where ``aux`` is a dict of scan-invariant inputs (positions, encoder output,
image embeddings, mode flags) and ``cache_slice`` is this block's slice of
the stacked per-kind cache (or None when stateless / training).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import Logical


@dataclasses.dataclass(frozen=True)
class BlockDef:
    kind: str
    init: Callable  # (key, cfg) -> (params, logical)
    apply: Callable  # (cfg, params, x, aux, cache) -> (x, new_cache)
    init_cache: Optional[Callable] = None  # (cfg, batch, shape_cfg) -> (cache, logical)


@dataclasses.dataclass(frozen=True)
class StackDef:
    pattern: Tuple[str, ...]   # block kinds within one group
    n_groups: int
    blocks: Dict[str, BlockDef]
    tail: Tuple[str, ...] = () # un-scanned trailing blocks (e.g. rgemma 26 = 8*3 + 2)


def init_stack(key, cfg, stack: StackDef):
    """Returns params dict:
      {"scan": {pos_idx: stacked_params}, "tail": {i: params}} + logical tree.
    """
    params: Dict[str, Any] = {"scan": {}, "tail": {}}
    logical: Dict[str, Any] = {"scan": {}, "tail": {}}
    keys = jax.random.split(key, len(stack.pattern) * stack.n_groups + len(stack.tail))
    ki = 0
    for pos, kind in enumerate(stack.pattern):
        bd = stack.blocks[kind]
        ks = jnp.stack([keys[ki + g] for g in range(stack.n_groups)])
        ki += stack.n_groups
        p, lg = jax.vmap(lambda k: bd.init(k, cfg)[0])(ks), bd.init(keys[0], cfg)[1]
        lg = jax.tree.map(lambda l: Logical("layers", *l.axes), lg,
                          is_leaf=lambda x: isinstance(x, Logical))
        params["scan"][f"{pos}_{kind}"] = p
        logical["scan"][f"{pos}_{kind}"] = lg
    for i, kind in enumerate(stack.tail):
        bd = stack.blocks[kind]
        p, lg = bd.init(keys[ki], cfg)
        ki += 1
        params["tail"][f"{i}_{kind}"] = p
        logical["tail"][f"{i}_{kind}"] = lg
    return params, logical


def init_stack_cache(cfg, stack: StackDef, batch: int, shape_cfg):
    """Zero caches, stacked [n_groups, ...] per pattern position (+ tail)."""
    cache: Dict[str, Any] = {"scan": {}, "tail": {}}
    logical: Dict[str, Any] = {"scan": {}, "tail": {}}
    for pos, kind in enumerate(stack.pattern):
        bd = stack.blocks[kind]
        if bd.init_cache is None:
            continue
        c, lg = bd.init_cache(cfg, batch, shape_cfg)
        c = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (stack.n_groups,) + a.shape), c)
        lg = jax.tree.map(lambda l: Logical("layers", *l.axes), lg,
                          is_leaf=lambda x: isinstance(x, Logical))
        cache["scan"][f"{pos}_{kind}"] = c
        logical["scan"][f"{pos}_{kind}"] = lg
    for i, kind in enumerate(stack.tail):
        bd = stack.blocks[kind]
        if bd.init_cache is None:
            continue
        c, lg = bd.init_cache(cfg, batch, shape_cfg)
        cache["tail"][f"{i}_{kind}"] = c
        logical["tail"][f"{i}_{kind}"] = lg
    return cache, logical


def apply_stack(cfg, stack: StackDef, params, x, aux,
                cache=None, *, remat: bool = True):
    """Run the stack. Returns (x, new_cache, aux_loss_sum)."""

    has_cache = cache is not None
    cached_keys = set(cache["scan"]) if has_cache else set()
    cached_tail = set(cache["tail"]) if has_cache else set()

    def group_body(x, scan_params, scan_cache):
        new_cache = {}
        aux_loss = jnp.zeros((), jnp.float32)
        for pos, kind in enumerate(stack.pattern):
            bd = stack.blocks[kind]
            key = f"{pos}_{kind}"
            c = scan_cache.get(key) if has_cache else None
            x, nc, al = bd.apply(cfg, scan_params[key], x, aux, c)
            aux_loss = aux_loss + al
            if key in cached_keys:
                new_cache[key] = nc
        return x, new_cache, aux_loss

    body = group_body
    if remat and cfg.remat:
        # nothing_saveable: full recompute. A save_only_these_names("tp_out")
        # policy was measured in §Perf iteration q1: it removes exactly the
        # two recompute-pass TP all-reduces per layer (-7% collective) but
        # costs +12 GB/device of saved activations — a losing trade while
        # HBM fit is the binding constraint, so it is not the default.
        body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_fn(carry, xs):
        x, aux_acc = carry
        scan_params, scan_cache = xs
        x, new_cache, aux_loss = body(x, scan_params, scan_cache)
        return (x, aux_acc + aux_loss), new_cache

    scan_cache_in = cache["scan"] if has_cache else {}
    (x, aux_total), new_scan_cache = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)),
        (params["scan"], scan_cache_in), length=stack.n_groups)

    new_tail_cache = {}
    for i, kind in enumerate(stack.tail):
        bd = stack.blocks[kind]
        key = f"{i}_{kind}"
        c = cache["tail"].get(key) if has_cache else None
        x, nc, al = bd.apply(cfg, params["tail"][key], x, aux, c)
        aux_total = aux_total + al
        if key in cached_tail:
            new_tail_cache[key] = nc

    new_cache = ({"scan": new_scan_cache, "tail": new_tail_cache}
                 if has_cache else None)
    return x, new_cache, aux_total
