"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM (matrix-memory LSTM): training/prefill uses the stabilized *chunkwise*
form — quadratic within a small chunk, recurrent (S_state, n, m) across
chunks — so the scan length is S/chunk and AD-saved carries stay small.
Decode uses the exact recurrent form. Both are validated against each other
in tests (and serve as the oracle for the Pallas kernel).

sLSTM has hidden-to-gate recurrence, so it is inherently sequential: a
``lax.scan`` over time with exponential-gating stabilizer state.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import Logical, shard_act

F32 = jnp.float32
NEG_INF = -1e30


def _logsig(x):
    return -jax.nn.softplus(-x)


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_params(key, cfg, dtype=None):
    """mLSTM block: up-proj x2, conv-less simplified variant, qkv heads,
    per-head scalar i/f gates, learnable skip gate, down-proj."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h = cfg.num_heads
    dqk = cfg.head_dim            # 192 for xlstm-125m
    dv = 2 * d // h               # value head dim (up-projection factor 2)
    inner = 2 * d
    ks = jax.random.split(key, 8)
    p = {
        "w_up": dense_init(ks[0], (d, inner), d, dtype),
        "w_gate": dense_init(ks[1], (d, inner), d, dtype),
        "w_q": dense_init(ks[2], (inner, h, dqk), inner, dtype),
        "w_k": dense_init(ks[3], (inner, h, dqk), inner, dtype),
        "w_v": dense_init(ks[4], (inner, h, dv), inner, dtype),
        "w_if": dense_init(ks[5], (inner, h, 2), inner, F32),
        "b_if": jnp.concatenate([jnp.zeros((h, 1), F32),
                                 jnp.ones((h, 1), F32) * 3.0], axis=1),
        "w_o": dense_init(ks[6], (h, dv, d), h * dv, dtype),
        "skip": jnp.zeros((inner,), F32),
    }
    lg = {
        "w_up": Logical("embed", "mlp"),
        "w_gate": Logical("embed", "mlp"),
        "w_q": Logical("mlp", "heads", None),
        "w_k": Logical("mlp", "heads", None),
        "w_v": Logical("mlp", "heads", None),
        "w_if": Logical("mlp", "heads", None),
        "b_if": Logical("heads", None),
        "w_o": Logical("heads", None, "embed"),
        "skip": Logical("mlp"),
    }
    return p, lg


def mlstm_recurrent_ref(q, k, v, li, lf, state=None):
    """Exact recurrent mLSTM. q,k: [B,S,H,Dk]; v: [B,S,H,Dv];
    li/lf: [B,S,H] (raw input-gate preact / log-sigmoid forget preact).
    state: (C [B,H,Dk,Dv], n [B,H,Dk], m [B,H]) or None.
    Returns (h [B,S,H,Dv], state)."""
    b, s, hh, dk = q.shape
    dv = v.shape[-1]
    scale = 1.0 / math.sqrt(dk)
    if state is None:
        c0 = jnp.zeros((b, hh, dk, dv), F32)
        n0 = jnp.zeros((b, hh, dk), F32)
        m0 = jnp.full((b, hh), NEG_INF, F32)
    else:
        c0, n0, m0 = state

    def step(carry, xs):
        c, n, m = carry
        qt, kt, vt, it, ft = xs  # [B,H,*]
        m_new = jnp.maximum(ft + m, it)
        alpha = jnp.exp(ft + m - m_new)
        beta = jnp.exp(it - m_new)
        c = alpha[..., None, None] * c + beta[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = alpha[..., None] * n + beta[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, c) * scale
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)) * scale,
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (c, n, m_new), h

    xs = tuple(jnp.moveaxis(a.astype(F32), 1, 0) for a in (q, k, v, li, lf))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1), (c, n, m)


def mlstm_chunkwise(q, k, v, li, lf, state=None, chunk: int = 256):
    """Stabilized chunkwise-parallel mLSTM (see module docstring)."""
    b, s, hh, dk = q.shape
    dv = v.shape[-1]
    if s % chunk or s <= chunk:
        return mlstm_recurrent_ref(q, k, v, li, lf, state)
    nc = s // chunk
    scale = 1.0 / math.sqrt(dk)

    def resh(a):
        return jnp.moveaxis(
            a.astype(F32).reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

    qc, kc, vc, lic, lfc = map(resh, (q, k, v, li, lf))  # [nc,B,C,H,*]
    if state is None:
        c0 = jnp.zeros((b, hh, dk, dv), F32)
        n0 = jnp.zeros((b, hh, dk), F32)
        m0 = jnp.full((b, hh), NEG_INF, F32)
    else:
        c0, n0, m0 = (x.astype(F32) for x in state)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, xs):
        c, n, m = carry
        qt, kt, vt, it, ft = xs                   # [B,C,H,*]
        bcum = jnp.cumsum(ft, axis=1)             # [B,C,H] inclusive logsig-f cumsum
        btot = bcum[:, -1]                        # [B,H]
        # intra-chunk decay D[t,s] = bcum[t] - bcum[s] + i[s], s<=t
        dmat = (bcum[:, :, None] - bcum[:, None, :] +
                it[:, None, :, :])                # [B,C(t),C(s),H]
        dmat = jnp.where(tri[None, :, :, None], dmat, NEG_INF)
        g = bcum + m[:, None, :]                  # inter log-scale [B,C,H]
        m_loc = jnp.maximum(jnp.max(dmat, axis=2), g)   # [B,C,H]
        w = jnp.exp(dmat - m_loc[:, :, None, :])        # [B,C,C,H]
        qk = jnp.einsum("bthk,bshk->btsh", qt, kt) * scale
        wqk = w * qk                                    # [B,C,C,H]
        inter_scale = jnp.exp(g - m_loc)                # [B,C,H]
        num = (jnp.einsum("btsh,bshv->bthv", wqk, vt)
               + inter_scale[..., None]
               * jnp.einsum("bthk,bhkv->bthv", qt, c) * scale)
        den_dot = (jnp.sum(wqk, axis=2)
                   + inter_scale * jnp.einsum("bthk,bhk->bth", qt, n) * scale)
        den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_loc))
        h = num / den[..., None]
        # state update to chunk end
        dend = btot[:, None, :] - bcum + it             # [B,C,H]
        m_new = jnp.maximum(btot + m, jnp.max(dend, axis=1))
        sc = jnp.exp(dend - m_new[:, None, :])          # [B,C,H]
        c = (jnp.exp(btot + m - m_new)[..., None, None] * c
             + jnp.einsum("bsh,bshk,bshv->bhkv", sc, kt, vt))
        n = (jnp.exp(btot + m - m_new)[..., None] * n
             + jnp.einsum("bsh,bshk->bhk", sc, kt))
        return (c, n, m_new), h

    (c, n, m), hs = jax.lax.scan(chunk_step, (c0, n0, m0),
                                 (qc, kc, vc, lic, lfc))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, hh, dv)
    return h, (c, n, m)


def mlstm_apply(cfg, p, x, cache=None):
    """x: [B,S,D]; cache {"c","n","m"} or None. Returns (y, new_cache)."""
    b, s, d = x.shape
    up = jnp.einsum("bsd,di->bsi", x, p["w_up"])
    gate = jnp.einsum("bsd,di->bsi", x, p["w_gate"])
    up = shard_act(up, "batch", None, "mlp")
    q = jnp.einsum("bsi,ihk->bshk", up, p["w_q"])
    k = jnp.einsum("bsi,ihk->bshk", up, p["w_k"])
    v = jnp.einsum("bsi,ihv->bshv", up, p["w_v"])
    gif = jnp.einsum("bsi,ihg->bshg", up.astype(F32), p["w_if"]) + p["b_if"]
    li, lf = gif[..., 0], _logsig(gif[..., 1])
    state = None
    if cache is not None:
        state = (cache["c"], cache["n"], cache["m"])
    if s == 1 and cache is not None:
        h, state = mlstm_recurrent_ref(q, k, v, li, lf, state)
    else:
        h, state = mlstm_chunkwise(q, k, v, li, lf, state)
    # gated inner stream (h lives in the 2D "inner" width: H * Dv == 2*D),
    # plus a learnable per-channel skip of the up-projected stream
    inner = h.reshape(b, s, -1).astype(F32)
    inner = inner * jax.nn.silu(gate.astype(F32)) + p["skip"] * up.astype(F32)
    inner = inner.astype(x.dtype).reshape(b, s, cfg.num_heads, -1)
    out = jnp.einsum("bshv,hvd->bsd", inner, p["w_o"])
    new_cache = None
    if cache is not None:
        c, n, m = state
        new_cache = {"c": c.astype(cache["c"].dtype),
                     "n": n.astype(cache["n"].dtype),
                     "m": m.astype(cache["m"].dtype)}
    return out, new_cache


def mlstm_cache(cfg, batch: int):
    h = cfg.num_heads
    dk = cfg.head_dim
    dv = 2 * cfg.d_model // h
    c = {"c": jnp.zeros((batch, h, dk, dv), F32),
         "n": jnp.zeros((batch, h, dk), F32),
         "m": jnp.full((batch, h), NEG_INF, F32)}
    lg = {"c": Logical("batch", "heads", None, None),
          "n": Logical("batch", "heads", None),
          "m": Logical("batch", "heads")}
    return c, lg


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_params(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 3)
    p = {
        # input gates: 4 gates (i,f,z,o) from x
        "w_gates": dense_init(ks[0], (d, 4, d), d, dtype),
        "b_gates": jnp.concatenate(
            [jnp.zeros((1, d)), jnp.ones((1, d)) * 3.0,
             jnp.zeros((2, d))], axis=0).astype(F32),
        # block-diagonal recurrent weights per head: [H,4,hd,hd]
        "r_gates": dense_init(ks[1], (h, 4, hd, hd), hd, dtype),
        "w_out": dense_init(ks[2], (d, d), d, dtype),
    }
    lg = {
        "w_gates": Logical("embed", None, "mlp"),
        "b_gates": Logical(None, "mlp"),
        "r_gates": Logical("heads", None, None, None),
        "w_out": Logical("mlp", "embed"),
    }
    return p, lg


def slstm_apply(cfg, p, x, cache=None):
    """Sequential sLSTM. x: [B,S,D]; cache {"c","n","h","m"}."""
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    wx = jnp.einsum("bsd,dgk->bsgk", x, p["w_gates"]).astype(F32) + p["b_gates"]

    if cache is not None:
        c0 = cache["c"].astype(F32)
        n0 = cache["n"].astype(F32)
        h0 = cache["h"].astype(F32)
        m0 = cache["m"].astype(F32)
    else:
        c0 = jnp.zeros((b, d), F32)
        n0 = jnp.ones((b, d), F32)
        h0 = jnp.zeros((b, d), F32)
        m0 = jnp.zeros((b, d), F32)

    r = p["r_gates"].astype(F32)

    def step(carry, wxt):
        c, n, hprev, m = carry
        hh = hprev.reshape(b, h, hd)
        rec = jnp.einsum("bhk,hgkj->bghj", hh, r).reshape(b, 4, d)
        g = wxt + rec
        li = g[:, 0]
        lf = _logsig(g[:, 1])
        z = jnp.tanh(g[:, 2])
        o = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(lf + m, li)
        ci = jnp.exp(lf + m - m_new)
        zi = jnp.exp(li - m_new)
        c_new = ci * c + zi * z
        n_new = ci * n + zi
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    wxs = jnp.moveaxis(wx, 1, 0)  # [S,B,4,D]
    (c, n, hl, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), wxs)
    y = jnp.moveaxis(hs, 0, 1)  # [B,S,D]
    out = jnp.einsum("bsd,dk->bsk", y.astype(x.dtype), p["w_out"])
    new_cache = None
    if cache is not None:
        new_cache = {"c": c.astype(cache["c"].dtype), "n": n.astype(cache["n"].dtype),
                     "h": hl.astype(cache["h"].dtype), "m": m.astype(cache["m"].dtype)}
    return out, new_cache


def slstm_cache(cfg, batch: int):
    d = cfg.d_model
    c = {"c": jnp.zeros((batch, d), F32), "n": jnp.ones((batch, d), F32),
         "h": jnp.zeros((batch, d), F32), "m": jnp.zeros((batch, d), F32)}
    lg = {k: Logical("batch", "mlp") for k in c}
    return c, lg
