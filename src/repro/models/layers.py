"""Shared neural-net layers for the model zoo.

All layers are pure functions over (params, inputs). Parameters are nested
dicts of jnp arrays; each builder also exposes a parallel tree of
``sharding.Logical`` leaves naming the logical axes of every parameter.

Attention comes in three memory-bounded flavours (pure jnp/lax — the Pallas
kernels in ``repro.kernels`` are drop-in replacements for the same math and
are validated against these in interpret mode):

* ``attention_train``   -- AD-friendly flash attention: outer ``lax.scan``
  over q blocks (emitting output blocks as ys), inner scan over kv blocks
  with online softmax. Causal masking is applied inside the block; for
  sliding-window attention the inner scan statically visits only the
  ``window/chunk + 1`` kv blocks that can intersect the window, so SWA
  training does no wasted block work.
* ``attention_prefill`` -- no-AD flash attention with *exact triangular*
  work: a single scan enumerates only the (q-block, kv-block) pairs that are
  live under the causal/SWA mask and scatters finished q blocks into an
  output buffer carried through the scan.
* ``attention_decode``  -- one-token attention against a (possibly ring-
  buffered) KV cache, unchunked; positions are explicit so ring buffers and
  partially-filled caches mask correctly.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import Logical, shard_act

F32 = jnp.float32
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# initializers / basics
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, F32) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32) + bias.astype(F32)).astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, half-split convention.

    x: [..., S, H, D]; positions: broadcastable to [..., S] (int32).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=F32) / half)
    ang = positions[..., None].astype(F32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _mask(q_pos, kv_pos, window: Optional[int], causal: bool):
    """q_pos: [..., Sq], kv_pos: [..., Sk] -> bool [..., Sq, Sk].

    kv_pos < 0 marks invalid (unfilled ring-buffer) slots.
    """
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    m = k >= 0
    if causal:
        m &= q >= k
    if window is not None:
        m &= (q - k) < window
    return m


def _softcap(logits, cap: Optional[float]):
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def _block_attn(q, k, v, qpos, kpos, *, window, causal, softcap, scale):
    """One flash block. q:[B,Q,Kv,G,D] k,v:[B,C,Kv,D] -> (s_max, p_sum, pv).

    Returns block statistics in f32 for the online-softmax combine.
    """
    logits = jnp.einsum("bqkgd,bckd->bqkgc", q.astype(F32), k.astype(F32)) * scale
    logits = _softcap(logits, softcap)
    msk = _mask(qpos, kpos, window, causal)[:, :, None, None, :]  # [B,Q,1,1,C]
    logits = jnp.where(msk, logits, NEG_INF)
    s_max = jnp.max(logits, axis=-1)                      # [B,Q,Kv,G]
    p = jnp.exp(logits - s_max[..., None])
    p = jnp.where(msk, p, 0.0)
    p_sum = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(F32))
    return s_max, p_sum, pv


def _combine(m, l, acc, s_max, p_sum, pv):
    m_new = jnp.maximum(m, s_max)
    alpha = jnp.exp(m - m_new)
    beta = jnp.exp(s_max - m_new)
    l_new = l * alpha + p_sum * beta
    acc_new = acc * alpha[..., None] + pv * beta[..., None]
    return m_new, l_new, acc_new


def _group(q, num_kv):
    """[B,S,H,D] -> [B,S,Kv,G,D]"""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def _ungroup(o):
    b, s, kv, g, d = o.shape
    return o.reshape(b, s, kv * g, d)


def attention_full(q, k, v, q_pos, kv_pos, *, window=None, causal=True,
                   softcap=None) -> jax.Array:
    """Unblocked reference attention (small S / decode / oracle)."""
    num_kv = k.shape[2]
    qg = _group(q, num_kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(F32), k.astype(F32)) * scale
    logits = _softcap(logits, softcap)
    msk = _mask(q_pos, kv_pos, window, causal)[:, :, None, None, :]
    logits = jnp.where(msk, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(msk, w, 0.0)  # rows with no valid kv -> 0
    o = jnp.einsum("bqkgs,bskd->bqkgd", w, v.astype(F32))
    return _ungroup(o).astype(q.dtype)


def attention_train(q, k, v, q_pos, kv_pos, *, window=None, causal=True,
                    softcap=None, q_chunk=512, kv_chunk=512) -> jax.Array:
    """AD-friendly flash attention (see module docstring)."""
    b, s, h, d = q.shape
    num_kv = k.shape[2]
    if s <= max(q_chunk, 1024) or s % q_chunk or k.shape[1] % kv_chunk:
        return attention_full(q, k, v, q_pos, kv_pos, window=window,
                              causal=causal, softcap=softcap)
    sk = k.shape[1]
    nq, nk = s // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)
    qg = _group(q, num_kv).reshape(b, nq, q_chunk, num_kv, h // num_kv, d)
    qg = jnp.moveaxis(qg, 1, 0)                       # [nq,B,Q,Kv,G,D]
    kb = k.reshape(b, nk, kv_chunk, num_kv, d)
    vb = v.reshape(b, nk, kv_chunk, num_kv, d)
    qp = jnp.broadcast_to(q_pos, (b, s)).reshape(b, nq, q_chunk)
    qp = jnp.moveaxis(qp, 1, 0)
    kp = jnp.broadcast_to(kv_pos, (b, sk)).reshape(b, nk, kv_chunk)

    # For SWA, only kv blocks within [i - window_blocks, i] can intersect.
    if window is not None and causal:
        wblocks = min(nk, window // kv_chunk + 2)
    else:
        wblocks = nk

    def q_step(_, qi):
        qblk, qpblk, i = qi

        def kv_step(carry, j):
            m, l, acc = carry
            jj = jnp.clip(j, 0, nk - 1)
            kblk = jax.lax.dynamic_index_in_dim(kb, jj, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vb, jj, 1, keepdims=False)
            kpb = jax.lax.dynamic_index_in_dim(kp, jj, 1, keepdims=False)
            kpb = jnp.where(j < 0, -1, kpb)  # out-of-range SWA block -> invalid
            s_max, p_sum, pv = _block_attn(qblk, kblk, vblk, qpblk, kpb,
                                           window=window, causal=causal,
                                           softcap=softcap, scale=scale)
            return _combine(m, l, acc, s_max, p_sum, pv), None

        m0 = jnp.full((b, q_chunk, num_kv, h // num_kv), NEG_INF, F32)
        l0 = jnp.zeros_like(m0)
        a0 = jnp.zeros((b, q_chunk, num_kv, h // num_kv, d), F32)
        if window is not None and causal:
            js = i - wblocks + 1 + jnp.arange(wblocks)
        else:
            js = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), js)
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.astype(q.dtype)

    idx = jnp.arange(nq)
    _, ob = jax.lax.scan(q_step, None, (qg, qp, idx))
    o = jnp.moveaxis(ob, 0, 1).reshape(b, s, num_kv, h // num_kv, d)
    return _ungroup(o)


def attention_prefill(q, k, v, q_pos, kv_pos, *, window=None, causal=True,
                      softcap=None, q_chunk=512, kv_chunk=512) -> jax.Array:
    """Exact-work flash attention for (no-grad) prefill.

    Enumerates only live (q-block, kv-block) pairs; finished q blocks are
    scattered into the carried output buffer. For causal full attention the
    live set is the lower triangle (exact triangular FLOPs); for SWA it is a
    band of width window/kv_chunk + 2.
    """
    b, s, h, d = q.shape
    num_kv = k.shape[2]
    if s <= max(q_chunk, 1024) or s % q_chunk or k.shape[1] % kv_chunk:
        return attention_full(q, k, v, q_pos, kv_pos, window=window,
                              causal=causal, softcap=softcap)
    sk = k.shape[1]
    nq, nk = s // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(d)
    qg = _group(q, num_kv).reshape(b, nq, q_chunk, num_kv, h // num_kv, d)
    kb = k.reshape(b, nk, kv_chunk, num_kv, d)
    vb = v.reshape(b, nk, kv_chunk, num_kv, d)
    qp = jnp.broadcast_to(q_pos, (b, s)).reshape(b, nq, q_chunk)
    kp = jnp.broadcast_to(kv_pos, (b, sk)).reshape(b, nk, kv_chunk)

    # static enumeration of live (i, j) pairs, row-major so each q block's
    # pairs are contiguous and the row ends at its diagonal block
    pairs = []
    if causal and window is not None:
        wblocks = min(nk, window // kv_chunk + 2)
        for i in range(nq):
            for j in range(max(0, i - wblocks + 1), i + 1):
                pairs.append((i, j))
    elif causal:
        for i in range(nq):
            for j in range(i + 1):
                pairs.append((i, j))
    else:
        for i in range(nq):
            for j in range(nk):
                pairs.append((i, j))
    ii = jnp.array([p[0] for p in pairs], jnp.int32)
    jj = jnp.array([p[1] for p in pairs], jnp.int32)
    flush = jnp.array([p1 == pairs[t + 1][0] if t + 1 < len(pairs) else True
                       for t, p1 in enumerate(p[0] for p in pairs)]) == False  # noqa: E712
    flush = jnp.array([(t + 1 == len(pairs)) or (pairs[t + 1][0] != p[0])
                       for t, p in enumerate(pairs)])

    g = h // num_kv
    m0 = jnp.full((b, q_chunk, num_kv, g), NEG_INF, F32)
    l0 = jnp.zeros_like(m0)
    a0 = jnp.zeros((b, q_chunk, num_kv, g, d), F32)
    o0 = jnp.zeros((nq, b, q_chunk, num_kv, g, d), q.dtype)

    def step(carry, t):
        o_buf, m, l, acc = carry
        i, j, fl = t
        qblk = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
        qpblk = jax.lax.dynamic_index_in_dim(qp, i, 1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        kpb = jax.lax.dynamic_index_in_dim(kp, j, 1, keepdims=False)
        s_max, p_sum, pv = _block_attn(qblk, kblk, vblk, qpblk, kpb,
                                       window=window, causal=causal,
                                       softcap=softcap, scale=scale)
        m, l, acc = _combine(m, l, acc, s_max, p_sum, pv)
        oblk = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        o_buf = jax.lax.cond(
            fl, lambda ob: jax.lax.dynamic_update_index_in_dim(ob, oblk, i, 0),
            lambda ob: ob, o_buf)
        # reset stats after a flush
        m = jnp.where(fl, m0, m)
        l = jnp.where(fl, l0, l)
        acc = jnp.where(fl, a0, acc)
        return (o_buf, m, l, acc), None

    (o_buf, _, _, _), _ = jax.lax.scan(step, (o0, m0, l0, a0), (ii, jj, flush))
    o = jnp.moveaxis(o_buf, 0, 1).reshape(b, s, num_kv, g, d)
    return _ungroup(o)


def attention_decode(q, k, v, q_pos, kv_pos, *, window=None, softcap=None):
    """Single-step decode attention. q: [B,1,H,D]; cache k/v: [B,S,Kv,D]."""
    return attention_full(q, k, v, q_pos, kv_pos, window=window, causal=True,
                          softcap=softcap)


# ---------------------------------------------------------------------------
# attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def attn_params(key, cfg, *, cross=False, dtype=None):
    """Parameters + logical specs for one attention block."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, (d, h, hd), d, dtype),
        "wk": dense_init(k2, (d, kv, hd), d, dtype),
        "wv": dense_init(k3, (d, kv, hd), d, dtype),
        "wo": dense_init(k4, (h, hd, d), h * hd, dtype),
    }
    lg = {
        "wq": Logical("embed", "heads", "head_dim"),
        "wk": Logical("embed", "kv_heads", "head_dim"),
        "wv": Logical("embed", "kv_heads", "head_dim"),
        "wo": Logical("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
        lg["bq"] = Logical("heads", "head_dim")
        lg["bk"] = Logical("kv_heads", "head_dim")
        lg["bv"] = Logical("kv_heads", "head_dim")
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), F32)
        p["k_norm"] = jnp.zeros((hd,), F32)
        lg["q_norm"] = Logical("head_dim")
        lg["k_norm"] = Logical("head_dim")
    return p, lg


def attn_project_qkv(cfg, p, x, positions, *, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard_act(q, "batch", None, "heads", None)
    k = shard_act(k, "batch", None, "kv_heads", None)
    v = shard_act(v, "batch", None, "kv_heads", None)
    return q, k, v


def attn_out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(key, cfg, d_ff=None, *, gated=True, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if gated:
        p = {"w_gate": dense_init(k1, (d, f), d, dtype),
             "w_up": dense_init(k2, (d, f), d, dtype),
             "w_down": dense_init(k3, (f, d), f, dtype)}
        lg = {"w_gate": Logical("embed", "mlp"),
              "w_up": Logical("embed", "mlp"),
              "w_down": Logical("mlp", "embed")}
    else:
        p = {"w_up": dense_init(k1, (d, f), d, dtype),
             "w_down": dense_init(k2, (f, d), f, dtype),
             "b_up": jnp.zeros((f,), dtype), "b_down": jnp.zeros((d,), dtype)}
        lg = {"w_up": Logical("embed", "mlp"), "w_down": Logical("mlp", "embed"),
              "b_up": Logical("mlp"), "b_down": Logical("embed")}
    return p, lg


def mlp_apply(cfg, p, x):
    act = activation(cfg.act)
    if "w_gate" in p:
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_up"]) + p["b_up"])
    h = shard_act(h, "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    if "b_down" in p:
        y = y + p["b_down"]
    return y
