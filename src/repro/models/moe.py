"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Dispatch is sort-free: positions-within-expert come from an exclusive cumsum
over the one-hot assignment matrix, then tokens are scattered into a
``[E, C, D]`` expert buffer (overflow beyond capacity C is dropped, standard
dropless-approximation) and gathered back with router weights.

Sharding regimes (resolved automatically by ``sharding.spec_for``):
  * E % model == 0 (OLMoE: 64 experts on 16-way model axis) -> expert
    parallelism: buffer and weights sharded over ``expert``; XLA inserts
    all-to-all-style collectives for the scatter/gather.
  * E % model != 0 (Grok-1: 8 experts) -> tensor parallelism *within* each
    expert: weight ``mlp`` axis sharded over ``model``; the expert buffer
    stays token-sharded.

FLOPs scale with E*C = tokens * top_k * capacity_factor, i.e. proportional
to *active* parameters (matters for the MODEL_FLOPS/HLO_FLOPs roofline
ratio).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation, dense_init
from repro.sharding import Logical, shard_act

F32 = jnp.float32


def _round_up(x, m):
    return ((x + m - 1) // m) * m


def moe_params(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": dense_init(k1, (d, e), d, F32),
        "w_gate": dense_init(k2, (e, d, f), d, dtype),
        "w_up": dense_init(k3, (e, d, f), d, dtype),
        "w_down": dense_init(k4, (e, f, d), f, dtype),
    }
    lg = {
        "router": Logical("embed", None),
        "w_gate": Logical("expert", "embed", "mlp"),
        "w_up": Logical("expert", "embed", "mlp"),
        "w_down": Logical("expert", "mlp", "embed"),
    }
    return p, lg


def capacity(cfg, num_tokens: int) -> int:
    tk = num_tokens * cfg.num_experts_per_tok
    c = int(tk * cfg.capacity_factor / cfg.num_experts)
    if c >= 128:
        return _round_up(c, 128)     # MXU-aligned for training shapes
    # decode / tiny groups: capacity can never exceed all assignments, and
    # a 128 floor would pad the expert buffer ~16x (§Perf iteration g2)
    return max(8, _round_up(min(max(c, 8), tk), 8))


def moe_apply(cfg, p, x):
    """x: [B, S, D] -> (y, aux_loss). Dispatch strategy per config:

    * ``dispatch="local"`` (default, the §Perf-optimized path): token
      routing/dispatch runs inside a ``jax.shard_map`` that is *manual*
      over the batch axes (pod, data) and *auto* over ``model`` — each
      data shard scatters only its own tokens into its own expert-capacity
      buffer, so no cross-shard gather/scatter exists for XLA to
      "involuntarily rematerialize". Expert weights stay auto-sharded
      (EP over `model` when E divides it, TP-within-expert otherwise).
    * ``dispatch="global"`` (paper-faithful baseline we measured first):
      plain-pjit global-capacity dispatch; SPMD partitioning falls back to
      replicating the expert buffer (see EXPERIMENTS.md §Perf iteration 1).
    """
    from repro.sharding import current_mesh
    mesh = current_mesh()
    batch_axes = tuple(a for a in ("pod", "data")
                       if mesh is not None and a in mesh.axis_names
                       and mesh.shape[a] > 1)
    g = _mesh_size(mesh, batch_axes) if batch_axes else 1
    b = x.shape[0]
    if g > 1 and b % g == 0:
        # §Perf iteration 1: grouped dispatch — split tokens into g groups
        # aligned with the batch sharding so every dispatch gather/scatter
        # is group-local; SPMD partitions the batched gather along the
        # sharded group dim instead of replicating the expert buffer.
        # (§Perf iteration 2 — pre-gathering the FSDP shard of the expert
        # weights here — was REFUTED: it made SPMD replicate the grouped
        # computation across the data axis, 10x compute. See EXPERIMENTS.)
        xg = x.reshape(g, b // g, *x.shape[1:])
        yg, aux = jax.vmap(
            lambda xb: _moe_apply_dense(cfg, p, xb, in_manual=True))(xg)
        return yg.reshape(x.shape), jnp.mean(aux)
    return _moe_apply_dense(cfg, p, x)


def _mesh_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _moe_apply_dense(cfg, p, x, in_manual: bool = False):
    """Capacity dispatch over whatever token set it is handed (global under
    plain pjit, per-shard under the shard_map wrapper). ``in_manual`` skips
    sharding constraints that reference manual (batch) mesh axes."""
    b, s, d = x.shape
    t = b * s
    k = cfg.num_experts_per_tok
    e = cfg.num_experts
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(F32), p["router"])   # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, eidx = jax.lax.top_k(probs, k)                          # [T,k]
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=F32), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(density * density_prob)

    # position-within-expert via exclusive cumsum over one-hot assignments
    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)                # [T,k,E]
    assign = jnp.sum(onehot, axis=1)                                 # [T,E]
    pos_base = jnp.cumsum(assign, axis=0) - assign                   # excl. over T
    # within a token, later of the k choices for the same expert offset by
    # the intra-token exclusive cumsum
    intra = jnp.cumsum(onehot, axis=1) - onehot                      # [T,k,E]
    pos = (pos_base[:, None, :] + intra)                             # [T,k,E]
    pos_tk = jnp.sum(pos * onehot, axis=-1)                          # [T,k]

    cap = capacity(cfg, t)
    keep = pos_tk < cap
    dest = eidx * cap + pos_tk                                       # [T,k]
    dest = jnp.where(keep, dest, e * cap)                            # drop row

    # Dispatch = tiny int32 slot->token scatter + row GATHER. Scattering
    # full rows makes XLA SPMD fall back to replicate-the-buffer (TBs of
    # model-axis all-gather at grok scale); scattering 4-byte indices and
    # gathering rows partitions cleanly.
    t_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)           # [T*k]
    slot_token = jnp.zeros((e * cap + 1,), jnp.int32).at[
        dest.reshape(-1)].set(t_flat + 1, mode="drop")[: e * cap]
    filled = slot_token > 0
    buf = xf[jnp.maximum(slot_token - 1, 0)]                         # [E*C, D]
    buf = jnp.where(filled[:, None], buf, 0).reshape(e, cap, d)
    if not in_manual:
        buf = shard_act(buf, "expert", "capacity", None)

    act = activation(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if not in_manual:
        h = shard_act(h, "expert", "capacity", "mlp")
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    if not in_manual:
        y = shard_act(y, "expert", "capacity", None)

    # gather back and combine with router weights (a bf16-combine variant
    # was tried and REFUTED — it repartitioned worse; see EXPERIMENTS §Perf)
    y_flat = jnp.concatenate([y.reshape(e * cap, d),
                              jnp.zeros((1, d), y.dtype)], axis=0)
    gathered = y_flat[dest.reshape(-1)].reshape(t, k, d)
    out = jnp.sum(gathered.astype(F32) * weights[..., None], axis=1)
    return out.reshape(b, s, d).astype(x.dtype), aux_loss
