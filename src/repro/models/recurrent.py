"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Training/prefill uses ``jax.lax.associative_scan`` over the linear
recurrence h_t = a_t * h_{t-1} + b_t (log-depth, TPU-friendly); decode
carries (h, conv-tap) state. All recurrence math in f32.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import Logical, shard_act

F32 = jnp.float32
_C = 8.0  # RG-LRU decay sharpness constant


def rglru_params(key, cfg, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d, w, cw = cfg.d_model, cfg.lru_width, cfg.conv1d_width
    ks = jax.random.split(key, 6)
    # Lambda init so the decay a = exp(-c*softplus(L)*r) lands in [0.9, 0.999]
    a0 = jnp.linspace(0.9, 0.999, w, dtype=F32)
    sp = -jnp.log(a0) / _C                      # softplus(L) target
    lam = jnp.log(jnp.expm1(sp))                # inverse softplus
    p = {
        "w_x": dense_init(ks[0], (d, w), d, dtype),
        "w_gate": dense_init(ks[1], (d, w), d, dtype),
        "conv_k": dense_init(ks[2], (cw, w), cw, F32),
        "conv_b": jnp.zeros((w,), F32),
        "w_r": dense_init(ks[3], (w, w), w, dtype),
        "b_r": jnp.zeros((w,), F32),
        "w_i": dense_init(ks[4], (w, w), w, dtype),
        "b_i": jnp.zeros((w,), F32),
        "lam": lam,
        "w_out": dense_init(ks[5], (w, d), w, dtype),
    }
    lg = {
        "w_x": Logical("embed", "lru"),
        "w_gate": Logical("embed", "lru"),
        "conv_k": Logical(None, "lru"),
        "conv_b": Logical("lru"),
        "w_r": Logical(None, "lru"),
        "b_r": Logical("lru"),
        "w_i": Logical(None, "lru"),
        "b_i": Logical("lru"),
        "lam": Logical("lru"),
        "w_out": Logical("lru", "embed"),
    }
    return p, lg


def _conv1d_causal(x, kernel, bias, state=None):
    """Depthwise causal conv. x: [B,S,W]; kernel: [CW,W].

    state: [B, CW-1, W] previous taps (decode) or None (train: zero pad).
    Returns (y, new_state).
    """
    cw = kernel.shape[0]
    xf = x.astype(F32)
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), F32)
    else:
        pad = state.astype(F32)
    full = jnp.concatenate([pad, xf], axis=1)          # [B, S+CW-1, W]
    y = jnp.zeros_like(xf)
    for j in range(cw):
        y = y + full[:, j:j + x.shape[1]] * kernel[cw - 1 - j]
    new_state = full[:, -(cw - 1):] if cw > 1 else pad
    return (y + bias).astype(x.dtype), new_state


def _gates(p, xc):
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["w_r"]).astype(F32) + p["b_r"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xc, p["w_i"]).astype(F32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r        # [B,S,W], <= 0
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xc.astype(F32))
    return a, gated_x


def rglru_scan(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t via associative scan. a,b: [B,S,W] f32."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(F32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_apply(cfg, p, x, cache=None):
    """x: [B,S,D]. cache: {"h": [B,W], "conv": [B,CW-1,W]} or None.

    Returns (y, new_cache).
    """
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    gate = jnp.einsum("bsd,dw->bsw", x, p["w_gate"])
    xb = shard_act(xb, "batch", None, "lru")
    gate = shard_act(gate, "batch", None, "lru")
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _conv1d_causal(xb, p["conv_k"], p["conv_b"], conv_state)
    a, b = _gates(p, xc)
    h0 = cache["h"] if cache is not None else None
    if x.shape[1] == 1 and cache is not None:  # decode fast path
        h = (a[:, 0] * h0.astype(F32) + b[:, 0])[:, None]
    else:
        h = rglru_scan(a, b, h0)
    y = jax.nn.gelu(gate.astype(F32)) * h
    out = jnp.einsum("bsw,wd->bsd", y.astype(x.dtype), p["w_out"])
    new_cache = None
    if cache is not None:
        new_cache = {"h": h[:, -1].astype(cache["h"].dtype), "conv": new_conv.astype(cache["conv"].dtype)}
    return out, new_cache


def rglru_cache(cfg, batch: int):
    w, cw = cfg.lru_width, cfg.conv1d_width
    c = {"h": jnp.zeros((batch, w), F32),
         "conv": jnp.zeros((batch, cw - 1, w), F32)}
    lg = {"h": Logical("batch", "lru"), "conv": Logical("batch", None, "lru")}
    return c, lg
