"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler detection, elastic re-mesh.

The driver is the piece a 1000-node deployment keeps identical: only the
failure *source* changes (injected exceptions here; preemptions / ICI
errors / host loss in production).

  * restart: any exception inside the step loop triggers restore from the
    latest checkpoint (params, optimizer state, data-iterator state) and a
    bounded number of resumes;
  * straggler detection: an EMA/deviation filter over per-step wall times;
    sustained outliers fire the mitigation hook (production: hot-spare
    swap / re-shard; here: recorded + pluggable);
  * elastic re-mesh: checkpoints are mesh-agnostic (see checkpointing),
    ``reshard_tree`` republishes a tree onto a new mesh's shardings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointing import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises at the configured global steps (once each)."""
    fail_at: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


class StragglerDetector:
    def __init__(self, window: int = 20, threshold: float = 3.0):
        self.window = window
        self.threshold = threshold
        self.times: List[float] = []
        self.events: List[Dict] = []

    def observe(self, step: int, dt: float,
                mitigate: Optional[Callable[[int], None]] = None):
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= self.window // 2 + 1:
            med = float(np.median(hist[:-1]))
            mad = float(np.median(np.abs(np.asarray(hist[:-1]) - med))) + 1e-9
            if dt > med + self.threshold * 6.0 * mad and dt > 1.5 * med:
                self.events.append({"step": step, "dt": dt, "median": med})
                if mitigate is not None:
                    mitigate(step)


def reshard_tree(tree, shardings):
    """Republish a pytree onto new shardings (elastic re-mesh)."""
    return jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s), tree, shardings)


@dataclasses.dataclass
class LoopResult:
    steps_run: int
    restarts: int
    final_step: int
    metrics_history: List[Dict]
    straggler_events: List[Dict]


def run_fault_tolerant(step_fn, params, opt_state, data_iter, *,
                       ckpt: CheckpointManager, total_steps: int,
                       checkpoint_every: int = 10,
                       injector: Optional[FailureInjector] = None,
                       max_restarts: int = 8,
                       on_metrics: Optional[Callable] = None) -> LoopResult:
    """Run `total_steps` of step_fn with checkpoint/restart semantics.

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    template = {"params": params, "opt": opt_state}
    restarts = 0
    history: List[Dict] = []
    straggler = StragglerDetector()

    restored = ckpt.restore_latest(template)
    if restored is not None:
        start, tree, extra = restored
        params, opt_state = tree["params"], tree["opt"]
        data_iter.load_state_dict(extra["data"])
        step = start
    else:
        step = 0
        ckpt.save(0, template, {"data": data_iter.state_dict()}, block=True)

    while step < total_steps:
        try:
            batch = next(data_iter)
            if injector is not None:
                injector.check(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            straggler.observe(step, dt)
            metrics = {k: float(v) for k, v in metrics.items()
                       if np.ndim(v) == 0}
            metrics["step"] = step
            history.append(metrics)
            if on_metrics is not None:
                on_metrics(step, metrics)
            step += 1
            if step % checkpoint_every == 0 or step == total_steps:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          {"data": data_iter.state_dict()})
        except Exception as e:  # noqa: BLE001 — restart on any step failure
            if isinstance(e, KeyboardInterrupt):
                raise
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait()
            restored = ckpt.restore_latest(template)
            assert restored is not None, "no checkpoint to restart from"
            step, tree, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            data_iter.load_state_dict(extra["data"])

    ckpt.wait()
    return LoopResult(steps_run=len(history), restarts=restarts,
                      final_step=step, metrics_history=history,
                      straggler_events=straggler.events)
