"""Synthetic GPGPU workload traces mirroring the paper's 15 applications.

The paper evaluates CUDA SDK / Rodinia / MARS / Lonestar binaries in
GPGPU-Sim; those cannot run here, so each application is represented by an
*address-stream generator* whose measured characteristics match what the
paper reports for that app class:

  * inter-warp hit-ratio heterogeneity (Fig 2): each warp draws a
    (working-set size, reuse-probability) archetype from the workload's
    class mixture, spanning all five warp types;
  * temporal stability (Fig 4): a warp keeps its archetype for the whole
    kernel, with optional slow phase shifts;
  * L2 pressure (Fig 5): ``intensity`` controls the compute gap between
    memory instructions, i.e. how hard the request stream hammers the
    cache queues.

Crucially the generator fixes only the ADDRESS STREAM — whether a request
hits is decided by the simulated cache under the policy being evaluated,
so policies can (and do) change warp hit ratios.

Generation itself lives in ``repro.core.tracegen``: a counter-RNG,
fully vectorized sampler (with a loop reference under exact-parity
tests) that also powers ``generate_batch`` multi-seed / multi-workload
stacks and the thousands-of-warps stress matrix. This module keeps the
paper's 15 ``WorkloadSpec`` entries and the original ``generate``
contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core import tracegen
from repro.core.tracegen import ARCHETYPES  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    suite: str
    # fraction of warps drawn from each archetype (sums to 1)
    mix: Tuple[float, float, float, float, float]  # allhit..allmiss order
    intensity: float          # 1 = memory bound (tiny compute gap)
    n_warps: int = 48
    n_instr: int = 64
    lines_per_instr: int = 16
    n_pcs: int = 12
    phase_shift: bool = False  # mid-kernel archetype change for some warps


# 15 applications, 4 suites — mixes chosen to span the paper's behaviours:
# graph workloads (Lonestar) are bimodal & memory-intensive, MARS map-reduce
# apps have large mostly-hit populations, Rodinia stencils are balanced,
# SDK kernels are streaming-heavy.
WORKLOADS: Dict[str, WorkloadSpec] = {s.name: s for s in [
    WorkloadSpec("BFS", "lonestar", (0.05, 0.25, 0.10, 0.35, 0.25), 0.95),
    WorkloadSpec("SSSP", "lonestar", (0.05, 0.25, 0.10, 0.30, 0.30), 0.95),
    WorkloadSpec("MST", "lonestar", (0.05, 0.20, 0.15, 0.35, 0.25), 0.85),
    WorkloadSpec("BH", "lonestar", (0.15, 0.35, 0.20, 0.20, 0.10), 0.70),
    WorkloadSpec("DMR", "lonestar", (0.05, 0.15, 0.30, 0.30, 0.20), 0.75),
    WorkloadSpec("PVC", "mars", (0.10, 0.45, 0.15, 0.20, 0.10), 0.80),
    WorkloadSpec("PVR", "mars", (0.10, 0.40, 0.20, 0.20, 0.10), 0.80),
    WorkloadSpec("SS", "mars", (0.15, 0.40, 0.15, 0.20, 0.10), 0.75),
    WorkloadSpec("IIX", "mars", (0.05, 0.30, 0.25, 0.25, 0.15), 0.85),
    WorkloadSpec("BP", "rodinia", (0.10, 0.30, 0.30, 0.20, 0.10), 0.60),
    WorkloadSpec("HS", "rodinia", (0.10, 0.25, 0.35, 0.20, 0.10), 0.55),
    WorkloadSpec("NW", "rodinia", (0.05, 0.20, 0.35, 0.25, 0.15), 0.65),
    WorkloadSpec("SRAD", "rodinia", (0.05, 0.25, 0.30, 0.25, 0.15), 0.70,
                 phase_shift=True),
    WorkloadSpec("CONS", "sdk", (0.02, 0.13, 0.20, 0.30, 0.35), 0.90),
    WorkloadSpec("SCP", "sdk", (0.02, 0.18, 0.25, 0.25, 0.30), 0.85),
]}

WORKLOAD_NAMES = tuple(WORKLOADS)


def generate(spec: WorkloadSpec, seed: int = 0):
    """Build the trace. Returns dict of numpy arrays:
      lines: i32[I, W, L]   cache-line addresses (-1 = inactive lane)
      pcs:   i32[I, W]      instruction PC ids
      compute_gap: f32      cycles between a warp's instructions
      archetype: i32[W]     ground-truth archetype per warp (for Fig 2/4)
    """
    return tracegen.generate(tracegen.TraceSpec.from_workload(spec), seed)


def generate_suite(workloads=WORKLOAD_NAMES, seeds=(0,)):
    """Stacked traces for several workloads × seeds (same shape required)
    — see ``tracegen.generate_batch`` for the output layout."""
    specs = [tracegen.TraceSpec.from_workload(WORKLOADS[w])
             for w in workloads]
    return tracegen.generate_batch(specs, seeds)
