"""Synthetic GPGPU workload traces mirroring the paper's 15 applications.

The paper evaluates CUDA SDK / Rodinia / MARS / Lonestar binaries in
GPGPU-Sim; those cannot run here, so each application is represented by an
*address-stream generator* whose measured characteristics match what the
paper reports for that app class:

  * inter-warp hit-ratio heterogeneity (Fig 2): each warp draws a
    (working-set size, reuse-probability) archetype from the workload's
    class mixture, spanning all five warp types;
  * temporal stability (Fig 4): a warp keeps its archetype for the whole
    kernel, with optional slow phase shifts;
  * L2 pressure (Fig 5): ``intensity`` controls the compute gap between
    memory instructions, i.e. how hard the request stream hammers the
    cache queues.

Crucially the generator fixes only the ADDRESS STREAM — whether a request
hits is decided by the simulated cache under the policy being evaluated,
so policies can (and do) change warp hit ratios.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Tuple

import numpy as np

# archetype = (working-set lines, reuse probability, shared-pool fraction)
ARCHETYPES = {
    "all_hit": (16, 0.998, 0.0),
    "mostly_hit": (24, 0.96, 0.05),
    "balanced": (64, 0.50, 0.10),
    "mostly_miss": (128, 0.15, 0.10),
    "all_miss": (0, 0.0, 0.0),
}


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    suite: str
    # fraction of warps drawn from each archetype (sums to 1)
    mix: Tuple[float, float, float, float, float]  # allhit..allmiss order
    intensity: float          # 1 = memory bound (tiny compute gap)
    n_warps: int = 48
    n_instr: int = 64
    lines_per_instr: int = 16
    n_pcs: int = 12
    phase_shift: bool = False  # mid-kernel archetype change for some warps


# 15 applications, 4 suites — mixes chosen to span the paper's behaviours:
# graph workloads (Lonestar) are bimodal & memory-intensive, MARS map-reduce
# apps have large mostly-hit populations, Rodinia stencils are balanced,
# SDK kernels are streaming-heavy.
WORKLOADS: Dict[str, WorkloadSpec] = {s.name: s for s in [
    WorkloadSpec("BFS", "lonestar", (0.05, 0.25, 0.10, 0.35, 0.25), 0.95),
    WorkloadSpec("SSSP", "lonestar", (0.05, 0.25, 0.10, 0.30, 0.30), 0.95),
    WorkloadSpec("MST", "lonestar", (0.05, 0.20, 0.15, 0.35, 0.25), 0.85),
    WorkloadSpec("BH", "lonestar", (0.15, 0.35, 0.20, 0.20, 0.10), 0.70),
    WorkloadSpec("DMR", "lonestar", (0.05, 0.15, 0.30, 0.30, 0.20), 0.75),
    WorkloadSpec("PVC", "mars", (0.10, 0.45, 0.15, 0.20, 0.10), 0.80),
    WorkloadSpec("PVR", "mars", (0.10, 0.40, 0.20, 0.20, 0.10), 0.80),
    WorkloadSpec("SS", "mars", (0.15, 0.40, 0.15, 0.20, 0.10), 0.75),
    WorkloadSpec("IIX", "mars", (0.05, 0.30, 0.25, 0.25, 0.15), 0.85),
    WorkloadSpec("BP", "rodinia", (0.10, 0.30, 0.30, 0.20, 0.10), 0.60),
    WorkloadSpec("HS", "rodinia", (0.10, 0.25, 0.35, 0.20, 0.10), 0.55),
    WorkloadSpec("NW", "rodinia", (0.05, 0.20, 0.35, 0.25, 0.15), 0.65),
    WorkloadSpec("SRAD", "rodinia", (0.05, 0.25, 0.30, 0.25, 0.15), 0.70,
                 phase_shift=True),
    WorkloadSpec("CONS", "sdk", (0.02, 0.13, 0.20, 0.30, 0.35), 0.90),
    WorkloadSpec("SCP", "sdk", (0.02, 0.18, 0.25, 0.25, 0.30), 0.85),
]}

WORKLOAD_NAMES = tuple(WORKLOADS)


def generate(spec: WorkloadSpec, seed: int = 0):
    """Build the trace. Returns dict of numpy arrays:
      lines: i32[I, W, L]   cache-line addresses (-1 = inactive lane)
      pcs:   i32[I, W]      instruction PC ids
      compute_gap: f32      cycles between a warp's instructions
      archetype: i32[W]     ground-truth archetype per warp (for Fig 2/4)
    """
    rng = np.random.default_rng(seed + zlib.crc32(spec.name.encode()))
    w, i, lpi = spec.n_warps, spec.n_instr, spec.lines_per_instr
    names = list(ARCHETYPES)
    arch_idx = rng.choice(len(names), size=w, p=np.asarray(spec.mix))
    # shared pool for inter-warp reuse (graph frontiers etc.)
    shared_pool = rng.integers(0, 1 << 20, size=256).astype(np.int64)

    lines = np.full((i, w, lpi), -1, np.int32)
    pcs = np.zeros((i, w), np.int32)

    for wi in range(w):
        at = names[arch_idx[wi]]
        ws_size, reuse, shared_frac = ARCHETYPES[at]
        if spec.phase_shift and rng.random() < 0.25:
            # this warp flips archetype half-way (Fig 4 long-term shift)
            at2 = names[rng.choice(len(names))]
        else:
            at2 = at
        # private working set: contiguous-ish region with stride spreading
        # across cache sets
        base = np.int32(wi) << 13
        ws = base + rng.choice(1 << 12, size=max(ws_size, 1), replace=False)
        pcs_w = rng.integers(0, 1 << 16, size=spec.n_pcs)
        # streaming region: disjoint per warp, int32-safe
        fresh_ctr = (1 << 22) + wi * (1 << 15)
        for ii in range(i):
            a_t = at if ii < i // 2 else at2
            ws_size_t, reuse_t, shared_t = ARCHETYPES[a_t]
            pcs[ii, wi] = pcs_w[ii % spec.n_pcs]
            for li in range(lpi):
                u = rng.random()
                if ws_size_t and u < reuse_t:
                    if shared_t and rng.random() < shared_t:
                        lines[ii, wi, li] = shared_pool[
                            rng.integers(0, len(shared_pool))]
                    else:
                        lines[ii, wi, li] = ws[rng.integers(0, len(ws))]
                else:
                    lines[ii, wi, li] = fresh_ctr
                    fresh_ctr += 1
    # warps of the same instruction touch nearby lines sometimes -> bank
    # conflicts emerge through the hash in the simulator
    compute_gap = np.float32(4.0 + (1.0 - spec.intensity) * 120.0)
    return {
        "lines": lines.astype(np.int32),
        "pcs": pcs,
        "compute_gap": compute_gap,
        "archetype": arch_idx.astype(np.int32),
    }
