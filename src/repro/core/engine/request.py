"""Per-request math shared by the event and wavefront engines.

Every function here is shape-polymorphic: the exact event loop calls them
with scalars (one request at a time), the wavefront loop with ``[N]``
vectors (one arrival-ordered wave at a time). Keeping the two engines on
the same decision/index/timing math is what makes the differential suite
(tests/test_engine_differential.py) meaningful: the engines may only
differ in *ordering* approximations, never in per-request semantics.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.core.engine.state import _QBINS, SimParams, SimState
from repro.policy import PolicyArrays, ops as POL

F32 = jnp.float32
I32 = jnp.int32

hash_index = POL.hash_index


# ---------------------------------------------------------------------------
# structure indexing (set / bank / channel / PC-table / EAF)
# ---------------------------------------------------------------------------

def bank_index(addr, prm: SimParams):
    return hash_index(addr, 1, prm.banks)


def set_index(addr, prm: SimParams):
    return hash_index(addr, 2, prm.sets)


def pc_index(pc, prm: SimParams):
    return hash_index(pc, 3, prm.pc_entries)


def dram_channel(addr, prm: SimParams):
    return hash_index(addr // prm.row_lines, 4, prm.dram_channels)


def dram_row(addr, prm: SimParams):
    return (addr // prm.row_lines).astype(I32)


def eaf_index(addr, prm: SimParams):
    return hash_index(addr, 5, prm.eaf_bits)


# ---------------------------------------------------------------------------
# ② bypass decision from current classifier / PC-table state
# ---------------------------------------------------------------------------

def bypass_decision_core(warp_type_w, accesses_w, token_w, pc_hits_v,
                         pc_acc_v, pc_req_v, addr, valid, prm: SimParams,
                         pa: PolicyArrays, oracle_wt, rand_u=None):
    """The bypass decision on fully-gathered inputs: per-warp classifier
    values AND the request's PC-table counter values. The innermost
    shared form — the cache-pass backends (``repro.kernels.cache_pass``)
    call it with their own PC-table representation (the fused sweep
    threads the tables across unrolled lanes; the Pallas kernel reads
    one-hot selections from VMEM scratch), which keeps all engines and
    backends on one copy of the mechanism-② math.
    """
    wtype = POL.select_label(pa, warp_type_w, oracle_wt)
    # periodic re-learning probe: the Nth access of each probe window
    # (cadence ``accesses``, which counts ALL valid requests, so it
    # keeps ticking while the warp bypasses) is forced down the cache
    # path. ``% pi == pi - 1`` — not ``== 0``, which would fire on a
    # warp's zeroth access instead of its Nth. The cadence is the traced
    # ``PolicyArrays.probe_interval`` (0 defers to SimParams).
    pi = POL.probe_interval(pa, prm.probe_interval).astype(I32)
    probe = (accesses_w % pi) == pi - 1
    # the tie-break draw is pure in ``addr`` — the fused sweep hoists it
    # out of the lane loop and passes it in precomputed
    if rand_u is None:
        rand_u = hash_index(addr, 7, 65536).astype(F32) / 65536.0
    byp = POL.bypass_decision(pa, wtype=wtype, probe=probe,
                              token_bit=token_w,
                              pc_hits=pc_hits_v,
                              pc_acc=pc_acc_v,
                              pc_req=pc_req_v, rand_u=rand_u)
    return byp & valid, wtype


def bypass_decision_vals(warp_type_w, accesses_w, token_w, st: SimState,
                         addr, pc, valid, prm: SimParams,
                         pa: PolicyArrays, oracle_wt):
    """``bypass_decision`` with the per-warp classifier inputs
    (``clf.warp_type[w]``, ``clf.accesses[w]``, ``tokens[w]``) passed
    as values instead of gathered here. The wavefront engine's fused
    path carries those as wave-resident vectors across the lane scan
    (each warp appears at most once per wave, so the carried slice is
    exactly what a fresh gather would read); the event path and the
    unfused wavefront path gather per call via ``bypass_decision``.
    """
    pidx = pc_index(pc, prm)
    byp, wtype = bypass_decision_core(
        warp_type_w, accesses_w, token_w, st.pc_hits[pidx],
        st.pc_acc[pidx], st.pc_req[pidx], addr, valid, prm, pa, oracle_wt)
    return byp, wtype, pidx


def bypass_decision(st: SimState, w, addr, pc, valid, prm: SimParams,
                    pa: PolicyArrays, tokens, oracle_wt):
    """Returns (byp, wtype, pidx) for one request or a wave of requests.

    ``oracle_wt`` is the trace generator's ground-truth per-phase label
    for the request's (instruction, warp); the policy's labeling mode
    (①) selects between it and the online classifier's label, so one
    vmapped sweep can compare oracle / online / stale labelings.

    Periodic probe so a reformed warp can be re-learned: every
    ``probe_interval``-th access of a bypassing warp still takes the
    cache path, and the classifier's window ratio is measured over that
    cache-path sample only (``classifier.observe``'s ``probed`` mask) —
    an undiluted probe stream is what lets a label ratchet back UP.
    """
    return bypass_decision_vals(st.clf.warp_type[w], st.clf.accesses[w],
                                tokens[w], st, addr, pc, valid, prm, pa,
                                oracle_wt)


# ---------------------------------------------------------------------------
# ③ insertion rank (policy + evicted-address-filter signal)
# ---------------------------------------------------------------------------

def insertion_rank(st: SimState, wtype, addr, prm: SimParams,
                   pa: PolicyArrays):
    # a filter bit is set iff it carries the current generation stamp
    # (the periodic EAF reset bumps the generation instead of clearing
    # the array — same semantics, no O(eaf_bits) work per request)
    ebit = st.eaf[eaf_index(addr, prm)] == st.eaf_gen
    return POL.insertion_rank(pa, wtype=wtype, eaf_bit=ebit,
                              rrip_max=prm.rrip_max)


# ---------------------------------------------------------------------------
# ④ DRAM row-buffer timing split
# ---------------------------------------------------------------------------

def dram_occ_lat(row_hit, prm: SimParams):
    """Row-hit/row-miss split into occupancy (pipelined throughput) and
    latency (critical path) components."""
    occ = jnp.where(row_hit, prm.occ_rowhit, prm.occ_rowmiss)
    lat = jnp.where(row_hit, prm.t_rowhit, prm.t_rowmiss)
    return occ, lat


# ---------------------------------------------------------------------------
# queuing-delay histogram binning (Fig 5)
# ---------------------------------------------------------------------------

def qdelay_bin(qdelay):
    """Map queue delays to their _QBINS histogram bin, elementwise."""
    edges = _QBINS[1:-1]
    return jnp.sum(qdelay[..., None] >= edges, axis=-1).astype(I32)


# ---------------------------------------------------------------------------
# end-of-simulation outputs shared by both engines
# ---------------------------------------------------------------------------

def finalize_outputs(st: SimState, ready, ratio_t, compute_gap, *,
                     n_instr: int, n_warps: int,
                     prm: SimParams) -> Dict[str, Any]:
    """Aggregate the final state into the public metrics dict."""
    makespan = jnp.max(ready)
    m = dict(st.metrics)
    total_instr = jnp.asarray(n_instr * n_warps, F32)
    # System throughput in a steady state where finished warps' slots are
    # backfilled by fresh thread blocks (as on a real GPU): the sum of
    # per-warp progress rates. makespan-based IPC is also reported.
    # compute_gap may be per-instruction (f32[I], phased intensity): each
    # warp's ready time includes one trailing gap — the last
    # instruction's.
    last_gap = compute_gap if jnp.ndim(compute_gap) == 0 else compute_gap[-1]
    per_warp_time = jnp.maximum(ready - last_gap, 1.0)
    ipc = jnp.sum(n_instr / per_warp_time)
    ipc_makespan = total_instr / jnp.maximum(makespan, 1.0)
    energy = (m["l2_accesses"] * prm.e_l2 + m["dram_accesses"] * prm.e_dram
              + makespan * prm.e_static)
    out = dict(m)
    out.update({
        "makespan": makespan,
        "ipc": ipc,
        "ipc_makespan": ipc_makespan,
        "warp_time": per_warp_time,
        "energy": energy,
        "perf_per_energy": ipc / energy * 1e3,
        "warp_hit_ratio": st.tot_hits / jnp.maximum(st.tot_acc, 1),
        "warp_type": st.clf.warp_type,
        "ratio_over_time": ratio_t,            # [I, W]
        "miss_rate": 1.0 - m["l2_hits"] / jnp.maximum(m["l2_accesses"], 1),
        "mean_qdelay": m["qdelay_sum"] / jnp.maximum(m["l2_accesses"], 1),
    })
    return out
