"""Wavefront simulation engine subsystem (ISSUE 3 tentpole).

Two interchangeable engines behind one API (DESIGN.md §9):

  * ``engine/event.py``     — the exact discrete-event reference loop
    (one earliest-ready warp per scan step; O(I·W·L) sequential);
  * ``engine/wavefront.py`` — the batched round-lockstep event loop
    (a wave of the ``wave_size`` earliest-ready warps per scan step,
    queue semantics recovered with sort-by-arrival + segmented prefix
    ops; runs the 1k–4k-warp stress matrix end-to-end);
  * ``engine/state.py``     — SimParams / SimState / init shared by both;
  * ``engine/request.py``   — per-request math shared by both.

``simulate`` / ``simulate_sweep`` keep their historical signatures and
grow an ``engine=`` argument; the default (``"event"``) is byte-identical
to the pre-split simulator, which the golden fig7 suite pins.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine import event as _event
from repro.core.engine import wavefront as _wavefront
from repro.core.engine.state import (N_QBINS, SimParams, SimState,
                                     init_state)
from repro.kernels.cache_pass.ops import BACKENDS as CACHE_BACKENDS
from repro.kernels.wavefront_scan.ops import BACKENDS as SCAN_BACKENDS
from repro.policy import Policy, stack_policies, to_arrays

ENGINES = ("event", "wavefront")


def validate_engine_args(engine: str, wave_size: Optional[int] = None,
                         scan_backend: str = "auto",
                         cache_backend: str = "auto") -> None:
    """Front-door validation shared by ``simulate``/``simulate_sweep`` and
    the declarative ``repro.api`` layer.

    Raises ``ValueError`` for an unknown engine, and — instead of silently
    ignoring it — for a ``wave_size``, non-default ``scan_backend`` or
    non-default ``cache_backend`` passed to any engine that does not
    consume one (only ``"wavefront"`` does). Catching a bad backend
    string here, before any tracing starts, is what keeps the failure a
    one-line ``ValueError`` with the allowed set instead of a shape
    error deep inside jit.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    if wave_size is not None:
        if engine != "wavefront":
            raise ValueError(
                f"wave_size={wave_size!r} is only meaningful with "
                f"engine='wavefront'; engine={engine!r} would silently "
                f"ignore it")
        if wave_size != int(wave_size):
            raise ValueError(
                f"wave_size must be an integer, got {wave_size!r}")
        if wave_size < 1:
            raise ValueError(f"wave_size must be >= 1, got {wave_size!r}")
    if scan_backend not in SCAN_BACKENDS:
        raise ValueError(
            f"unknown scan_backend {scan_backend!r}; choose from "
            f"{SCAN_BACKENDS}")
    if scan_backend != "auto" and engine != "wavefront":
        raise ValueError(
            f"scan_backend={scan_backend!r} is only meaningful with "
            f"engine='wavefront'; engine={engine!r} would silently "
            f"ignore it")
    if cache_backend not in CACHE_BACKENDS:
        raise ValueError(
            f"unknown cache_backend {cache_backend!r}; choose from "
            f"{CACHE_BACKENDS}")
    if cache_backend != "auto" and engine != "wavefront":
        raise ValueError(
            f"cache_backend={cache_backend!r} is only meaningful with "
            f"engine='wavefront'; engine={engine!r} would silently "
            f"ignore it")


def validate_mesh_args(mesh, policy_axes=None, seed_axes=None,
                       warp_axes=None, engine: str = "event") -> None:
    """Front-door validation for the multi-device sweep knobs.

    Mesh-axis assignments without a mesh, axis names the mesh does not
    carry, one mesh axis claimed by two sweep axes, and warp-axis
    sharding on an engine without a sharded-warp path all fail here with
    a one-line ``ValueError`` — before any device placement or tracing.
    (Divisibility is NOT validated: an axis product that does not divide
    its dimension falls back to replication, ``sharding.resolve_axes``.)
    """
    from repro import sharding as SH
    named = {"policy_axes": SH.norm_axes(policy_axes),
             "seed_axes": SH.norm_axes(seed_axes),
             "warp_axes": SH.norm_axes(warp_axes)}
    if mesh is None:
        given = [k for k, v in named.items() if v is not None]
        if given:
            raise ValueError(f"{', '.join(given)} given without a mesh; "
                             "pass mesh= as well")
        return
    present = set(mesh.axis_names)
    for k, axes in named.items():
        for a in axes or ():
            if a not in present:
                raise ValueError(
                    f"{k} names mesh axis {a!r} but the mesh only has "
                    f"axes {tuple(mesh.axis_names)}")
    claimed: dict = {}
    for k, axes in named.items():
        for a in axes or ():
            if a in claimed:
                raise ValueError(
                    f"mesh axis {a!r} is claimed by both {claimed[a]} "
                    f"and {k}; each sweep axis needs its own mesh axes")
            claimed[a] = k
    if named["warp_axes"] is not None and engine != "wavefront":
        raise ValueError(
            f"warp_axes={warp_axes!r} is only meaningful with "
            f"engine='wavefront' (the sharded-warp path); "
            f"engine={engine!r} would silently ignore it")


def _core(engine: str, wave_size: Optional[int], scan_backend: str,
          cache_backend: str, warp_mesh=None, warp_axes=None):
    validate_engine_args(engine, wave_size, scan_backend, cache_backend)
    if engine == "event":
        return _event.simulate_core
    return partial(_wavefront.simulate_core, wave_size=wave_size,
                   scan_backend=scan_backend, cache_backend=cache_backend,
                   warp_mesh=warp_mesh, warp_axes=warp_axes)


def _oracle_or_zeros(oracle_types, trace_lines, policies):
    """Resolve the ground-truth label input. A policy with
    labeling="oracle" READS these labels, so omitting them there is a
    caller error (zeros would silently label every warp all-miss);
    otherwise the labels are never read and a zero placeholder keeps the
    jit signature uniform. Shape follows the trace minus lanes."""
    if oracle_types is not None:
        return oracle_types
    needs = [p.name for p in policies if p.labeling == "oracle"]
    if needs:
        raise ValueError(
            f"policies {needs} use labeling='oracle' but no oracle_types "
            "were passed; supply the trace's 'oracle_wtype' array "
            "(repro.core.tracegen emits it for every spec)")
    return jnp.zeros(trace_lines.shape[:-1], jnp.int32)


@partial(jax.jit,
         static_argnames=("prm", "n_warps", "lanes", "engine", "wave_size",
                          "scan_backend", "cache_backend", "warp_mesh",
                          "warp_axes"))
def _simulate_one(trace_lines, trace_pcs, compute_gap, oracle_types, pa, *,
                  n_warps: int, lanes: int, prm: SimParams,
                  engine: str = "event",
                  wave_size: Optional[int] = None,
                  scan_backend: str = "auto",
                  cache_backend: str = "auto",
                  warp_mesh=None, warp_axes=None) -> Dict[str, Any]:
    core = _core(engine, wave_size, scan_backend, cache_backend,
                 warp_mesh, warp_axes)
    return core(trace_lines, trace_pcs, compute_gap, oracle_types, pa,
                n_warps=n_warps, lanes=lanes, prm=prm)


@partial(jax.jit,
         static_argnames=("prm", "n_warps", "lanes", "engine", "wave_size",
                          "scan_backend", "cache_backend", "warp_mesh",
                          "warp_axes"))
def _simulate_batch(trace_lines, trace_pcs, compute_gap, oracle_types,
                    pa_batch, *, n_warps: int, lanes: int, prm: SimParams,
                    engine: str = "event",
                    wave_size: Optional[int] = None,
                    scan_backend: str = "auto",
                    cache_backend: str = "auto",
                    warp_mesh=None, warp_axes=None):
    one = partial(_core(engine, wave_size, scan_backend, cache_backend,
                        warp_mesh, warp_axes),
                  n_warps=n_warps, lanes=lanes, prm=prm)
    if trace_lines.ndim == 4:      # seed-stacked traces [S, I, W, L]
        over_seeds = jax.vmap(one, in_axes=(0, 0, 0, 0, None))
        return jax.vmap(over_seeds, in_axes=(None, None, None, None, 0))(
            trace_lines, trace_pcs, compute_gap, oracle_types, pa_batch)
    return jax.vmap(one, in_axes=(None, None, None, None, 0))(
        trace_lines, trace_pcs, compute_gap, oracle_types, pa_batch)


def simulate(trace_lines, trace_pcs, compute_gap, *, n_warps: int,
             lanes: int, prm: SimParams, pol: Policy,
             engine: str = "event", wave_size: Optional[int] = None,
             scan_backend: str = "auto", cache_backend: str = "auto",
             oracle_types=None, mesh=None, warp_axes=None
             ) -> Dict[str, Any]:
    """Run one workload under one policy.

    ``engine="event"`` (default) is the exact discrete-event reference:
    each outer step pops the globally earliest ready warp, so queue
    counters are updated chronologically (up to intra-instruction lane
    skew). ``engine="wavefront"`` batches ``wave_size`` earliest-ready
    warps per step (default ``max(min(W, 8), W//6)``, widening to
    ``W//4`` above 256 warps — see ``wavefront.default_wave_size``) —
    within the documented tolerance of the event path (DESIGN.md §9)
    and the only path that completes the tracegen stress matrix.

    The policy enters as a traced `PolicyArrays`, so every `Policy` preset
    reuses the same compiled executable for a given workload shape.

    ``scan_backend`` selects the wavefront timing-pass implementation
    (``repro.kernels.wavefront_scan``) and ``cache_backend`` the
    cache-pass one (``repro.kernels.cache_pass``): ``"auto"`` (default)
    picks the fused one-sweep path on CPU and the Pallas kernel on TPU,
    both output-identical to ``"ref"``, the unfused pre-fusion form kept
    for in-run perf A/Bs. The two knobs compose freely.

    trace_lines: i32[I, W, L]; trace_pcs: i32[I, W]; compute_gap: f32
    scalar or f32[I] (phased per-instruction intensity); oracle_types:
    optional i32[I, W] ground-truth labels — required (pass the trace's
    ``oracle_wtype``) when the policy's labeling mode is "oracle".
    Returns metrics dict (all jnp arrays).

    ``mesh`` + ``warp_axes`` enable the wavefront engine's sharded-warp
    path: the warp axis of the trace arrays and the per-warp machine
    state is constrained to those mesh axes (replication fallback when
    the axis product does not divide ``n_warps``). Output-identical to
    the unsharded run — sharding is placement, never semantics.
    """
    validate_engine_args(engine, wave_size, scan_backend, cache_backend)
    validate_mesh_args(mesh, warp_axes=warp_axes, engine=engine)
    from repro import sharding as SH
    w_res = SH.resolve_axes(mesh, warp_axes, n_warps)
    return _simulate_one(trace_lines, trace_pcs, compute_gap,
                         _oracle_or_zeros(oracle_types, trace_lines,
                                          (pol,)),
                         to_arrays(pol), n_warps=n_warps, lanes=lanes,
                         prm=prm, engine=engine, wave_size=wave_size,
                         scan_backend=scan_backend,
                         cache_backend=cache_backend,
                         warp_mesh=mesh if w_res is not None else None,
                         warp_axes=w_res)


def simulate_sweep(trace_lines, trace_pcs, compute_gap,
                   policies: Sequence[Policy], *, n_warps: int, lanes: int,
                   prm: SimParams, engine: str = "event",
                   wave_size: Optional[int] = None,
                   scan_backend: str = "auto",
                   cache_backend: str = "auto",
                   oracle_types=None, mesh=None, policy_axes=None,
                   seed_axes=None, warp_axes=None) -> Dict[str, Any]:
    """Run a whole policy sweep in ONE jitted, vmapped call.

    trace_lines may be [I, W, L] (one workload instance — outputs get a
    leading policy axis P) or seed-stacked [S, I, W, L] (outputs get
    leading axes [P, S]); trace_pcs/compute_gap/oracle_types follow suit
    (compute_gap gains a trailing [I] axis for phased specs whose
    schedule varies intensity).

    ``oracle_types`` (i32[(S,) I, W], the trace's ``oracle_wtype``) is
    only read by policies with labeling="oracle" — passing it lets one
    vmapped sweep compare oracle / online / stale labelings.

    Multi-device placement (``mesh`` + any of the three axis knobs):
    ``policy_axes`` shards the stacked policy axis of the traced
    ``PolicyArrays``, ``seed_axes`` the seed-stack axis of the trace
    arrays, and ``warp_axes`` the warp axis INSIDE the wavefront engine
    (trace storage + per-warp machine state). Every (policy, seed) cell
    of the vmapped sweep is an independent simulation, so batch-axis
    sharding is pure data parallelism and the outputs are bitwise
    identical to the unsharded call (pinned by
    tests/test_sharded_sweep.py). Any axis whose mesh product does not
    divide its dimension falls back to replication.

    Metrics match per-policy `simulate` calls bit-for-bit on either
    engine (the parity is enforced by tests/test_policy_engine.py).
    """
    validate_engine_args(engine, wave_size, scan_backend, cache_backend)
    validate_mesh_args(mesh, policy_axes, seed_axes, warp_axes, engine)
    pa = stack_policies(policies)
    oracle = _oracle_or_zeros(oracle_types, trace_lines, policies)
    w_res = None
    if mesh is not None:
        from repro import sharding as SH
        p_res = SH.resolve_axes(mesh, policy_axes, len(policies))
        pa = jax.tree.map(lambda a: SH.put_leading(a, mesh, p_res), pa)
        s_res = None
        if jnp.ndim(trace_lines) == 4:     # seed-stacked [S, I, W, L]
            s_res = SH.resolve_axes(mesh, seed_axes,
                                    trace_lines.shape[0])
        trace_lines = SH.put_leading(trace_lines, mesh, s_res)
        trace_pcs = SH.put_leading(trace_pcs, mesh, s_res)
        oracle = SH.put_leading(oracle, mesh, s_res)
        gap_res = s_res if jnp.ndim(compute_gap) >= 1 else None
        compute_gap = SH.put_leading(compute_gap, mesh, gap_res)
        w_res = SH.resolve_axes(mesh, warp_axes, n_warps)
    return _simulate_batch(trace_lines, trace_pcs, compute_gap, oracle,
                           pa, n_warps=n_warps, lanes=lanes, prm=prm,
                           engine=engine, wave_size=wave_size,
                           scan_backend=scan_backend,
                           cache_backend=cache_backend,
                           warp_mesh=mesh if w_res is not None else None,
                           warp_axes=w_res)


__all__ = [
    "CACHE_BACKENDS", "ENGINES", "N_QBINS", "SCAN_BACKENDS", "SimParams",
    "SimState", "init_state", "simulate", "simulate_sweep",
    "validate_engine_args", "validate_mesh_args",
]
