"""Exact discrete-event reference engine (``engine="event"``).

True discrete-event order: each outer step pops the globally earliest
ready warp and services its next memory instruction's requests one at a
time, so every queue counter is updated chronologically (up to
intra-instruction lane skew). This is the fidelity reference the
wavefront engine is differentially tested against — and the reason it is
O(I·W) *sequential* scan steps with an inner per-lane scan, which is
what caps it far below the stress-matrix warp counts.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import classifier as CLF
from repro.core.engine import request as REQ
from repro.core.engine.state import SimParams, SimState, init_state
from repro.policy import PolicyArrays, ops as POL

F32 = jnp.float32
I32 = jnp.int32


def _request_step(st: SimState, req, prm: SimParams, pa: PolicyArrays,
                  tokens) -> tuple:
    """Service ONE request against the full state, chronologically exact."""
    t_arr, w, addr, pc, valid, owt = req
    m = st.metrics

    # ---- ①② label select + bypass decision (branchless, repro.policy) ------
    byp, wtype, pidx = REQ.bypass_decision(st, w, addr, pc, valid, prm, pa,
                                           tokens, owt)
    use_l2 = valid & ~byp

    # ---- L2 bank queue (O3) ------------------------------------------------
    bank = REQ.bank_index(addr, prm)
    t_head = jnp.maximum(st.bank_free[bank], t_arr)
    bank_free = st.bank_free.at[bank].set(
        jnp.where(use_l2, t_head + prm.l2_svc, st.bank_free[bank]))
    qdelay = jnp.where(use_l2, t_head - t_arr, 0.0)

    # ---- L2 lookup ----------------------------------------------------------
    sidx = REQ.set_index(addr, prm)
    tset = st.tags[sidx]
    is_line = tset == addr
    hit = jnp.any(is_line) & use_l2
    hit_way = jnp.argmax(is_line)
    rset = st.rrip[sidx]
    rset = jnp.where(hit, rset.at[hit_way].set(0), rset)

    # ---- ③ fill + insertion (branchless, repro.policy) ---------------------
    allocate = use_l2 & ~hit
    # SRRIP aging to make a victim available
    shift = prm.rrip_max - jnp.max(rset)
    rset_aged = rset + jnp.where(allocate, shift, 0)
    victim = jnp.argmax(rset_aged)
    evicted = tset[victim]
    victim_type = st.meta_type[sidx, victim]   # read BEFORE the overwrite

    rank = REQ.insertion_rank(st, wtype, addr, prm, pa)

    tags = st.tags.at[sidx, victim].set(jnp.where(allocate, addr, evicted))
    rrip = st.rrip.at[sidx].set(
        jnp.where(allocate, rset_aged.at[victim].set(rank), rset))
    meta_type = st.meta_type.at[sidx, victim].set(
        jnp.where(allocate, wtype, victim_type))

    # EAF bookkeeping: remember evicted addresses; the periodic reset is
    # a generation bump (state.py), not an array clear
    ev_valid = allocate & (evicted >= 0)
    eidx = REQ.eaf_index(evicted, prm)
    eaf = st.eaf.at[eidx].set(
        jnp.where(ev_valid, st.eaf_gen, st.eaf[eidx]))
    eaf_ctr = st.eaf_ctr + ev_valid.astype(I32)
    reset = eaf_ctr >= prm.eaf_capacity
    eaf_gen = jnp.where(reset, st.eaf_gen + 1, st.eaf_gen)
    eaf_ctr = jnp.where(reset, 0, eaf_ctr)

    # ---- ④ DRAM two-queue FR-FCFS (branchless, repro.policy) ---------------
    go_dram = valid & (byp | ~hit)
    t_dram_arr = jnp.where(byp, t_arr, t_head + prm.l2_lat)
    ch = REQ.dram_channel(addr, prm)
    row = REQ.dram_row(addr, prm)
    row_hit = (st.cur_row[ch] == row) & go_dram
    occ, lat = REQ.dram_occ_lat(row_hit, prm)
    hp = POL.is_high_priority(pa, wtype)
    t0_hp = jnp.maximum(st.hp_free[ch], t_dram_arr)
    t0_lp = jnp.maximum(jnp.maximum(st.lp_free[ch], st.hp_free[ch]),
                        t_dram_arr)
    t0 = jnp.where(hp, t0_hp, t0_lp)
    hp_free = st.hp_free.at[ch].set(
        jnp.where(go_dram & hp, t0 + occ, st.hp_free[ch]))
    lp_free = st.lp_free.at[ch].set(
        jnp.where(go_dram & ~hp, t0 + occ, st.lp_free[ch]))
    cur_row = st.cur_row.at[ch].set(jnp.where(go_dram, row, st.cur_row[ch]))
    t_done_dram = t0 + lat

    t_done = jnp.where(hit, t_head + prm.l2_lat, t_done_dram)
    t_done = jnp.where(valid, t_done, t_arr)

    # ---- ① classifier + PC table + lifetime counters ------------------------
    # sampling window, probe cadence and label-freeze cap are
    # policy-visible knobs; ``probed`` marks the cache-path requests so
    # the window ratio is measured over the undiluted probe sample
    clf = CLF.observe(st.clf, w, hit,
                      sampling_interval=POL.reclass_interval(
                          pa, prm.sampling_interval),
                      mostly_hit_threshold=prm.mostly_hit_threshold,
                      mostly_miss_threshold=prm.mostly_miss_threshold,
                      weight=jnp.atleast_1d(valid.astype(I32)),
                      max_windows=POL.reclass_max_windows(pa),
                      probed=jnp.atleast_1d(use_l2.astype(I32)),
                      probe_interval=POL.probe_interval(
                          pa, prm.probe_interval))
    pc_hits = st.pc_hits.at[pidx].add((hit & use_l2).astype(I32))
    pc_acc = st.pc_acc.at[pidx].add(use_l2.astype(I32))
    pc_req = st.pc_req.at[pidx].add(valid.astype(I32))
    tot_hits = st.tot_hits.at[w].add(hit.astype(I32))
    tot_acc = st.tot_acc.at[w].add(valid.astype(I32))

    # ---- metrics -------------------------------------------------------------
    qbin = REQ.qdelay_bin(qdelay)
    metrics = dict(m)
    metrics["qdelay_hist"] = m["qdelay_hist"].at[qbin].add(use_l2.astype(I32))
    metrics["qdelay_sum"] = m["qdelay_sum"] + qdelay
    metrics["l2_accesses"] = m["l2_accesses"] + use_l2.astype(I32)
    metrics["l2_hits"] = m["l2_hits"] + hit.astype(I32)
    metrics["dram_accesses"] = m["dram_accesses"] + go_dram.astype(I32)
    metrics["row_hits"] = m["row_hits"] + row_hit.astype(I32)
    metrics["bypasses"] = m["bypasses"] + byp.astype(I32)
    metrics["evictions_by_type"] = m["evictions_by_type"].at[
        victim_type].add(ev_valid.astype(I32))

    new_st = st._replace(
        tags=tags, rrip=rrip, meta_type=meta_type, bank_free=bank_free,
        cur_row=cur_row, hp_free=hp_free, lp_free=lp_free, clf=clf,
        eaf=eaf, eaf_gen=eaf_gen, eaf_ctr=eaf_ctr, pc_hits=pc_hits,
        pc_acc=pc_acc, pc_req=pc_req, tot_hits=tot_hits, tot_acc=tot_acc,
        metrics=metrics)
    return new_st, t_done


def simulate_core(trace_lines, trace_pcs, compute_gap, oracle_types,
                  pa: PolicyArrays, *, n_warps: int, lanes: int,
                  prm: SimParams) -> Dict[str, Any]:
    """One workload × one policy. `pa` is a traced pytree — vmappable.

    ``compute_gap`` is a scalar or f32[I] (phased per-instruction
    intensity); ``oracle_types`` is i32[I, W] ground-truth labels (only
    read by policies whose labeling mode is "oracle")."""
    n_instr = trace_lines.shape[0]
    tokens = POL.pcal_tokens(pa, n_warps)

    # [W, I, ...] layout for per-warp program counters
    lines_wi = jnp.swapaxes(trace_lines, 0, 1)
    pcs_wi = jnp.swapaxes(trace_pcs, 0, 1)
    oracle_wi = jnp.swapaxes(oracle_types, 0, 1)

    st0 = init_state(n_warps, prm)
    ready0 = jnp.zeros((n_warps,), F32)
    ptr0 = jnp.zeros((n_warps,), I32)

    def event_step(carry, _):
        st, ready, ptr = carry
        active = ptr < n_instr
        w = jnp.argmin(jnp.where(active, ready, jnp.inf)).astype(I32)
        i = ptr[w]
        lines = lines_wi[w, i]                        # [L]
        pc = pcs_wi[w, i]
        t0 = ready[w]
        lanes_idx = jnp.arange(lanes, dtype=I32)
        t_arr = t0 + lanes_idx.astype(F32) * prm.lane_skew
        valid = lines >= 0

        def body(s, r):
            return _request_step(s, r, prm, pa, tokens)

        reqs = (t_arr, jnp.full((lanes,), w, I32), lines,
                jnp.full((lanes,), pc, I32), valid,
                jnp.full((lanes,), oracle_wi[w, i], I32))
        st, dones = jax.lax.scan(body, st, reqs)
        dmax = jnp.max(jnp.where(valid, dones, -jnp.inf))
        dmin = jnp.min(jnp.where(valid, dones, jnp.inf))
        has_req = jnp.isfinite(dmax)
        stall = jnp.where(has_req, dmax - dmin, 0.0)
        metrics = dict(st.metrics)
        metrics["stall_cycles"] = metrics["stall_cycles"] + stall
        st = st._replace(metrics=metrics)
        gap = compute_gap if jnp.ndim(compute_gap) == 0 else compute_gap[i]
        new_ready = ready.at[w].set(
            jnp.where(has_req, dmax + gap, t0 + gap))
        new_ptr = ptr.at[w].add(1)
        # snapshot for Fig 4: (warp, instr index, sampled ratio)
        snap = (w, i, st.clf.ratio[w])
        return (st, new_ready, new_ptr), snap

    (st, ready, _), snaps = jax.lax.scan(
        event_step, (st0, ready0, ptr0), None, length=n_instr * n_warps)

    # scatter snapshots into a [I, W] ratio-over-time matrix
    sw, si, sr = snaps
    ratio_t = jnp.zeros((n_instr, n_warps), F32).at[si, sw].set(sr)

    return REQ.finalize_outputs(st, ready, ratio_t, compute_gap,
                                n_instr=n_instr, n_warps=n_warps, prm=prm)
