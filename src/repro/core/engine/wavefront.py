"""Wavefront engine (``engine="wavefront"``): batched round-lockstep
event loop.

Instead of popping ONE earliest-ready warp per `lax.scan` step (the exact
event engine), each step pops a *wave* of the ``wave_size`` earliest-ready
warps and services all their W×L requests vectorized. Because the wave is
selected by readiness, its requests are close together in simulated time,
which is what makes batched processing faithful. Each wave runs two
passes:

  1. **Cache pass** (scan over the L lanes): bypass decisions, tag
     lookup, RRIP fill/eviction, EAF and PC-table bookkeeping, and the
     classifier update (an O(B) gather/scatter form of
     ``classifier.observe``). A lane sub-step carries at most ONE
     request per warp, so the batched observe is equivalent to the event
     loop's sequential per-request observes (warp ids are distinct —
     pinned by the differential suite). None of these outcomes depend on
     request *timing*, so the pass needs no queue state. Cross-slot
     structural conflicts inside one sub-step (two wave warps filling
     the same cache set) resolve last-write-wins in chronological slot
     order via masked scatters.

  2. **Timing pass** (no scan): all B×L requests of the wave, in
     warp-major chronological order (the event loop's pop-and-service
     order), go through segmented prefix queue recovery —
     for the requests of one bank/channel queue, ``start_j = c_j +
     max_{i<=j}(max(t_i, free) - c_i)`` where ``c`` is the exclusive
     prefix sum of service occupancy (a cumsum + cummax per queue yields
     exactly the sequential FR-FCFS arrival-order service times). The
     DRAM row-buffer chain links each request to its true chronological
     predecessor in its channel, and the low-priority queue's floor
     folds in the running busy horizon of the wave's high-priority chain
     (strict priority, as in the event engine).

The approximation ladder (DESIGN.md §9): event (wave of 1, exact) →
wavefront (wave of W/6, W/4 at stress populations — near-chronological;
the differential suite pins the envelope) → full round-lockstep
(``wave_size=n_warps`` — one scan step services an entire instruction
round). A wave of one warp reduces every prefix op to the event
engine's scalar update, so single-warp traces match the event path
exactly.

Cost: O((I·W/B + I) · L) sequential sub-steps with O(B)-vectorized work
each, vs the event loop's O(I·W·L) sequential steps — this is what runs
the 1k–4k-warp stress matrix (tracegen/stress.py) end-to-end.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import classifier as CLF
from repro.core import warp_types as WT
from repro.core.engine import request as REQ
from repro.core.engine.state import SimParams, SimState, init_state
from repro.policy import PolicyArrays, ops as POL

F32 = jnp.float32
I32 = jnp.int32

_NEG = -jnp.inf


def default_wave_size(n_warps: int) -> int:
    """Readiness-window size. W/6 keeps a wave chronologically tight
    (warp populations that drifted apart never share a wave); calibrated
    on the 15-workload × 4-policy differential matrix at the paper's 48
    warps (worst |IPC| deviation 1.9%, worst makespan deviation 2.1% —
    DESIGN.md §9). Above the differential-verified zone the stress
    populations are W/4-waved: thousands of statistically similar warps
    keep waves relatively tight, and the wider wave amortizes per-step
    cost further."""
    if n_warps > 256:
        return n_warps // 4
    return max(min(n_warps, 8), n_warps // 6)


def _observe_gathered(clf: CLF.ClassifierState, w, is_hit, weight,
                      prm: SimParams, pa: PolicyArrays
                      ) -> CLF.ClassifierState:
    """``classifier.observe`` restricted to the B touched warps.

    Equivalent to the full-width observe — an untouched warp's counters
    don't change, so its window can never reset on this call — but costs
    O(B) gather/scatter instead of O(W) elementwise work per sub-step,
    which is what keeps the cache pass O(B) at stress-scale warp counts.
    Wave warp ids are distinct, so the scatters don't collide. Parity
    with `CLF.observe` is pinned by tests/test_engine_differential.py.

    The sampling window and label-freeze cap come from the policy
    (①, same knobs the event engine passes to ``CLF.observe``).
    """
    interval = POL.reclass_interval(pa, prm.sampling_interval)
    max_windows = POL.reclass_max_windows(pa)
    hits = clf.hits[w] + is_hit.astype(I32) * weight
    accesses = clf.accesses[w] + weight
    due = accesses >= interval
    ratio_now = hits.astype(jnp.float32) / jnp.maximum(accesses, 1)
    new_type = WT.classify(ratio_now, accesses,
                           mostly_hit_threshold=prm.mostly_hit_threshold,
                           mostly_miss_threshold=prm.mostly_miss_threshold)
    relabel = due & (clf.windows[w] < max_windows)
    return CLF.ClassifierState(
        hits=clf.hits.at[w].set(jnp.where(due, 0, hits)),
        accesses=clf.accesses.at[w].set(jnp.where(due, 0, accesses)),
        warp_type=clf.warp_type.at[w].set(
            jnp.where(relabel, new_type, clf.warp_type[w])),
        ratio=clf.ratio.at[w].set(jnp.where(due, ratio_now, clf.ratio[w])),
        windows=clf.windows.at[w].add(due.astype(I32)),
    )


def _cache_pass(st: SimState, t_arr, w, addr, pc, valid, owt,
                prm: SimParams, pa: PolicyArrays, tokens) -> tuple:
    """One lane sub-step of a wave: the timing-independent half of
    ``event._request_step`` for [B] requests (at most one per warp),
    slots in chronological order."""
    m = st.metrics

    # ---- ①② label select + bypass decision (shared branchless math) --------
    byp, wtype, pidx = REQ.bypass_decision(st, w, addr, pc, valid, prm, pa,
                                           tokens, owt)
    use_l2 = valid & ~byp

    # ---- L2 lookup (sub-step-start tags) -----------------------------------
    sidx = REQ.set_index(addr, prm)
    tset = st.tags[sidx]                              # [B, ways]
    is_line = tset == addr[:, None]
    hit = jnp.any(is_line, axis=1) & use_l2
    hit_way = jnp.argmax(is_line, axis=1)
    way_oh = jnp.arange(prm.ways, dtype=I32)[None, :] == hit_way[:, None]
    rset = st.rrip[sidx]
    rset = jnp.where(hit[:, None] & way_oh, 0, rset)

    # ---- ③ fill + insertion -------------------------------------------------
    allocate = use_l2 & ~hit
    shift = prm.rrip_max - jnp.max(rset, axis=1)
    rset_aged = rset + jnp.where(allocate, shift, 0)[:, None]
    victim = jnp.argmax(rset_aged, axis=1)
    evicted = jnp.take_along_axis(tset, victim[:, None], axis=1)[:, 0]
    victim_type = st.meta_type[sidx, victim]          # read BEFORE overwrite
    rank = REQ.insertion_rank(st, wtype, addr, prm, pa)

    # masked scatters: an out-of-bounds set index drops the update, and
    # duplicate-set conflicts resolve last-write-wins in arrival order
    s_alloc = jnp.where(allocate, sidx, prm.sets)
    tags = st.tags.at[s_alloc, victim].set(addr, mode="drop")
    vict_oh = jnp.arange(prm.ways, dtype=I32)[None, :] == victim[:, None]
    new_row = jnp.where(allocate[:, None],
                        jnp.where(vict_oh, rank[:, None], rset_aged), rset)
    s_l2 = jnp.where(use_l2, sidx, prm.sets)
    rrip = st.rrip.at[s_l2].set(new_row, mode="drop")
    meta_type = st.meta_type.at[s_alloc, victim].set(wtype, mode="drop")

    # EAF bookkeeping: remember evicted addresses; the periodic reset is
    # a generation bump (state.py), not an array clear
    ev_valid = allocate & (evicted >= 0)
    eidx = REQ.eaf_index(evicted, prm)
    eaf = st.eaf.at[jnp.where(ev_valid, eidx, prm.eaf_bits)].set(
        st.eaf_gen, mode="drop")
    eaf_ctr = st.eaf_ctr + jnp.sum(ev_valid.astype(I32))
    reset = eaf_ctr >= prm.eaf_capacity
    eaf_gen = jnp.where(reset, st.eaf_gen + 1, st.eaf_gen)
    eaf_ctr = jnp.where(reset, 0, eaf_ctr)

    # ---- ① classifier + PC table + lifetime counters ------------------------
    clf = _observe_gathered(st.clf, w, hit, valid.astype(I32), prm, pa)
    pc_hits = st.pc_hits.at[pidx].add((hit & use_l2).astype(I32))
    pc_acc = st.pc_acc.at[pidx].add(use_l2.astype(I32))
    tot_hits = st.tot_hits.at[w].add(hit.astype(I32))
    tot_acc = st.tot_acc.at[w].add(valid.astype(I32))

    metrics = dict(m)
    metrics["l2_accesses"] = m["l2_accesses"] + jnp.sum(use_l2.astype(I32))
    metrics["l2_hits"] = m["l2_hits"] + jnp.sum(hit.astype(I32))
    metrics["bypasses"] = m["bypasses"] + jnp.sum(byp.astype(I32))
    metrics["evictions_by_type"] = m["evictions_by_type"].at[
        victim_type].add(ev_valid.astype(I32))

    new_st = st._replace(
        tags=tags, rrip=rrip, meta_type=meta_type, clf=clf, eaf=eaf,
        eaf_gen=eaf_gen, eaf_ctr=eaf_ctr, pc_hits=pc_hits, pc_acc=pc_acc,
        tot_hits=tot_hits, tot_acc=tot_acc, metrics=metrics)
    hp = POL.is_high_priority(pa, wtype)
    return new_st, (t_arr, addr, valid, byp, use_l2, hit, hp)


class QueueAnchors(NamedTuple):
    """Per-queue service frontier, in two time axes.

    ``*_ts`` is the largest L2-arrival (wave sort) time the queue has
    serviced; ``*_sa`` the largest service-arrival time (equal to
    ``*_ts`` for banks, but DRAM requests arrive at ``t_head + l2_lat``
    after the L2 queue, so the axes differ there). Together with the
    queue's busy-until (``bank_free``/``hp_free``/``lp_free`` in
    SimState) they summarize the queue's backlog for the next wave:
    ``backlog = free - sa``.
    """
    bank_ts: jnp.ndarray     # f32[banks]
    hp_ts: jnp.ndarray       # f32[channels]
    hp_sa: jnp.ndarray       # f32[channels]
    lp_ts: jnp.ndarray       # f32[channels]
    lp_sa: jnp.ndarray       # f32[channels]


def init_anchors(prm: SimParams) -> QueueAnchors:
    c = jnp.full((prm.dram_channels,), _NEG, F32)
    return QueueAnchors(bank_ts=jnp.full((prm.banks,), _NEG, F32),
                        hp_ts=c, hp_sa=c, lp_ts=c, lp_sa=c)


def _carry_floor(free, last_ts, last_sa, t_s, t_svc):
    """Work-conserving carry floor [Q, N] for the next wave's requests.

    A request at/after the queue's serviced frontier (``t_s >= last_ts``)
    waits for the full busy-until, exactly like the event engine. A
    *retrograde* request — its warp raced ahead of the warps that last
    used the queue, so in true event order it would have been serviced
    amid that backlog, not after it — sees the queue's STANDING BACKLOG
    (``free - last_sa``) anchored at its own service-arrival time instead
    of the absolute end-of-service. Single-warp traces are always at the
    frontier, so they stay exact.
    """
    backlog = (free - last_sa)[:, None]              # +inf if queue unused
    interp = jnp.minimum(free[:, None], t_svc[None, :] + backlog)
    return jnp.where(t_s[None, :] >= last_ts[:, None], free[:, None],
                     interp)


def _anchor_update(last, mask, t):
    return jnp.maximum(last,
                       jnp.max(jnp.where(mask, t[None, :], _NEG), axis=1))


def _queue_prefix(mask, t_arr, occ, free):
    """FIFO service start times for one queue family, vectorized.

    mask: bool[Q, N] — request j belongs to queue q; slots in
    chronological order. t_arr: f32[N] arrivals; occ: f32[N] per-request
    occupancy; free: f32[Q, 1|N] per-slot busy-until floor.

    Returns (start[Q, N], end[Q, N]); ``end`` is -inf outside ``mask`` so
    row-wise maxima skip those entries.
    """
    occ_m = jnp.where(mask, occ[None, :], 0.0)
    c = jnp.cumsum(occ_m, axis=1) - occ_m            # exclusive prefix occ
    v = jnp.where(mask, jnp.maximum(t_arr[None, :], free) - c, _NEG)
    start = c + jax.lax.cummax(v, axis=1)
    end = jnp.where(mask, start + occ_m, _NEG)
    return start, end


def _timing_pass(st: SimState, an: QueueAnchors, recs,
                 prm: SimParams) -> tuple:
    """Arrival-ordered queue recovery for one wave's B×L requests.

    Chronological bank/channel semantics come from segmented prefix
    (cumsum/cummax) ops per L2 bank, DRAM channel and priority class over
    the wave's requests in WARP-MAJOR order — warp slots ascend in ready
    time (the wave selection argsort) and a warp's lanes stay
    consecutive, which is exactly the event loop's processing order (pop
    the earliest warp, service all its lanes back-to-back). Interleaving
    by raw per-lane arrival instead would shred the DRAM row-buffer
    streaks a streaming warp's consecutive lines produce. Cross-wave
    carry uses the work-conserving backlog floor (``_carry_floor``).
    """
    t_s, addr_s, valid_s, byp_s, use_l2_s, hit_s, hp_s = \
        [jnp.swapaxes(x, 0, 1).reshape(-1) for x in recs]  # [N = B*L]
    n = t_s.shape[0]
    slot = jnp.arange(n, dtype=I32)
    # a wave of ONE warp is the event loop — no batching to compensate
    # for, so the carry floor is the plain busy-until (bitwise parity
    # with engine="event", asserted by the differential suite)
    exact = recs[0].shape[1] == 1

    def carry_floor(free, last_ts, last_sa, t_svc):
        if exact:
            return free[:, None]
        return _carry_floor(free, last_ts, last_sa, t_s, t_svc)

    # ---- L2 bank queues (O3) ----------------------------------------------
    bank = REQ.bank_index(addr_s, prm)
    bmask = (bank[None, :] == jnp.arange(prm.banks, dtype=I32)[:, None]) \
        & use_l2_s[None, :]
    svc = jnp.full((n,), prm.l2_svc, F32)
    b_start, b_end = _queue_prefix(
        bmask, t_s, svc,
        carry_floor(st.bank_free, an.bank_ts, an.bank_ts, t_s))
    t_head = jnp.sum(jnp.where(bmask, b_start, 0.0), axis=0)
    bank_free = jnp.maximum(st.bank_free, jnp.max(b_end, axis=1))
    qdelay = jnp.where(use_l2_s, t_head - t_s, 0.0)

    # ---- ④ DRAM two-queue FR-FCFS ------------------------------------------
    go_dram = valid_s & (byp_s | ~hit_s)
    t_dram_arr = jnp.where(byp_s, t_s, t_head + prm.l2_lat)
    ch = REQ.dram_channel(addr_s, prm)
    row = REQ.dram_row(addr_s, prm)
    n_ch = prm.dram_channels
    cmask = (ch[None, :] == jnp.arange(n_ch, dtype=I32)[:, None]) \
        & go_dram[None, :]

    # row-buffer chain: each request's predecessor is the previous
    # request in its channel within this wave, else the carried open row
    inc = jax.lax.cummax(jnp.where(cmask, slot[None, :], -1), axis=1)
    prev_idx = jnp.concatenate(
        [jnp.full((n_ch, 1), -1, I32), inc[:, :-1]], axis=1)
    prev_row = jnp.where(prev_idx >= 0,
                         jnp.take(row, jnp.maximum(prev_idx, 0)),
                         st.cur_row[:, None])
    row_hit = (prev_row == row[None, :])[ch, slot] & go_dram
    occ, lat = REQ.dram_occ_lat(row_hit, prm)

    mask_hp = cmask & hp_s[None, :]
    hp_carry = carry_floor(st.hp_free, an.hp_ts, an.hp_sa, t_dram_arr)
    hp_start, hp_end = _queue_prefix(mask_hp, t_dram_arr, occ, hp_carry)
    # strict priority: a low-priority request waits for the high queue's
    # busy horizon at its chronological position
    hp_busy = jnp.concatenate(
        [jnp.full((n_ch, 1), _NEG),
         jax.lax.cummax(hp_end, axis=1)[:, :-1]], axis=1)
    lp_floor = jnp.maximum(
        carry_floor(st.lp_free, an.lp_ts, an.lp_sa, t_dram_arr),
        jnp.maximum(hp_carry, hp_busy))
    mask_lp = cmask & ~hp_s[None, :]
    lp_start, lp_end = _queue_prefix(mask_lp, t_dram_arr, occ, lp_floor)

    t0 = jnp.where(hp_s, hp_start[ch, slot], lp_start[ch, slot])
    hp_free = jnp.maximum(st.hp_free, jnp.max(hp_end, axis=1))
    lp_free = jnp.maximum(st.lp_free, jnp.max(lp_end, axis=1))
    last_idx = inc[:, -1]
    cur_row = jnp.where(last_idx >= 0,
                        jnp.take(row, jnp.maximum(last_idx, 0)),
                        st.cur_row)

    t_done = jnp.where(hit_s, t_head + prm.l2_lat, t0 + lat)
    t_done = jnp.where(valid_s, t_done, t_s)

    # ---- metrics ------------------------------------------------------------
    m = st.metrics
    qbin = REQ.qdelay_bin(qdelay)
    metrics = dict(m)
    metrics["qdelay_hist"] = m["qdelay_hist"].at[qbin].add(
        use_l2_s.astype(I32))
    metrics["qdelay_sum"] = m["qdelay_sum"] + jnp.sum(qdelay)
    metrics["dram_accesses"] = m["dram_accesses"] + jnp.sum(
        go_dram.astype(I32))
    metrics["row_hits"] = m["row_hits"] + jnp.sum(row_hit.astype(I32))

    new_st = st._replace(bank_free=bank_free, cur_row=cur_row,
                         hp_free=hp_free, lp_free=lp_free, metrics=metrics)
    new_an = QueueAnchors(
        bank_ts=_anchor_update(an.bank_ts, bmask, t_s),
        hp_ts=_anchor_update(an.hp_ts, mask_hp, t_s),
        hp_sa=_anchor_update(an.hp_sa, mask_hp, t_dram_arr),
        lp_ts=_anchor_update(an.lp_ts, mask_lp, t_s),
        lp_sa=_anchor_update(an.lp_sa, mask_lp, t_dram_arr))
    # back to the cache pass's [L, B] layout
    lanes, b = recs[0].shape
    t_done_lb = jnp.swapaxes(t_done.reshape(b, lanes), 0, 1)
    return new_st, new_an, t_done_lb


def simulate_core(trace_lines, trace_pcs, compute_gap, oracle_types,
                  pa: PolicyArrays, *, n_warps: int, lanes: int,
                  prm: SimParams,
                  wave_size: Optional[int] = None) -> Dict[str, Any]:
    """One workload × one policy on the wavefront engine. Vmappable.

    ``compute_gap`` is a scalar or f32[I]; ``oracle_types`` i32[I, W]
    (same contract as ``event.simulate_core``)."""
    n_instr = trace_lines.shape[0]
    B = max(1, min(wave_size or default_wave_size(n_warps), n_warps))
    # phase 1 (>= B warps active) services B instructions per wave; once
    # fewer than B warps remain every wave advances all of them, so at
    # most n_instr further waves finish the tail
    n_waves = -(-n_instr * n_warps // B) + n_instr
    tokens = POL.pcal_tokens(pa, n_warps)

    lines_wi = jnp.swapaxes(trace_lines, 0, 1)      # [W, I, L]
    pcs_wi = jnp.swapaxes(trace_pcs, 0, 1)          # [W, I]
    oracle_wi = jnp.swapaxes(oracle_types, 0, 1)    # [W, I]

    st0 = init_state(n_warps, prm)
    an0 = init_anchors(prm)
    ready0 = jnp.zeros((n_warps,), F32)
    ptr0 = jnp.zeros((n_warps,), I32)
    ratio0 = jnp.zeros((n_instr, n_warps), F32)

    def wave_step(carry, _):
        st, an, ready, ptr, ratio_t = carry
        active = ptr < n_instr
        # wave = the B earliest-ready active warps; the stable argsort
        # leaves slots in chronological order (ties by warp id, like the
        # event loop's argmin)
        order = jnp.argsort(jnp.where(active, ready, jnp.inf))
        w_sel = order[:B].astype(I32)
        slot_ok = active[w_sel]
        i_sel = ptr[w_sel]
        t0 = ready[w_sel]
        lines_b = lines_wi[w_sel, i_sel]             # [B, L]
        pc_b = pcs_wi[w_sel, i_sel]                  # [B]
        owt_b = oracle_wi[w_sel, i_sel]              # [B]

        def lane_step(s, xs):
            lane, addr = xs                          # i32[], i32[B]
            valid = (addr >= 0) & slot_ok
            t_arr = t0 + lane.astype(F32) * prm.lane_skew
            return _cache_pass(s, t_arr, w_sel, addr, pc_b, valid, owt_b,
                               prm, pa, tokens)

        st, recs = jax.lax.scan(
            lane_step, st,
            (jnp.arange(lanes, dtype=I32), jnp.swapaxes(lines_b, 0, 1)))
        st, an, t_done = _timing_pass(st, an, recs, prm)     # [L, B]

        valid_lb = recs[2]
        dmax = jnp.max(jnp.where(valid_lb, t_done, -jnp.inf), axis=0)
        dmin = jnp.min(jnp.where(valid_lb, t_done, jnp.inf), axis=0)
        has_req = jnp.isfinite(dmax)
        stall = jnp.where(has_req & slot_ok, dmax - dmin, 0.0)
        metrics = dict(st.metrics)
        metrics["stall_cycles"] = metrics["stall_cycles"] + jnp.sum(stall)
        st = st._replace(metrics=metrics)

        w_ok = jnp.where(slot_ok, w_sel, n_warps)    # OOB -> dropped
        gap = compute_gap if jnp.ndim(compute_gap) == 0 \
            else compute_gap[i_sel]
        ready = ready.at[w_ok].set(
            jnp.where(has_req, dmax + gap, t0 + gap),
            mode="drop")
        ptr = ptr.at[w_ok].add(1, mode="drop")
        # Fig 4 snapshot: sampled ratio after each serviced instruction
        ratio_t = ratio_t.at[i_sel, w_ok].set(st.clf.ratio[w_sel],
                                              mode="drop")
        return (st, an, ready, ptr, ratio_t), None

    (st, _, ready, _, ratio_t), _ = jax.lax.scan(
        wave_step, (st0, an0, ready0, ptr0, ratio0), None, length=n_waves)

    return REQ.finalize_outputs(st, ready, ratio_t, compute_gap,
                                n_instr=n_instr, n_warps=n_warps, prm=prm)
