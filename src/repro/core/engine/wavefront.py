"""Wavefront engine (``engine="wavefront"``): batched round-lockstep
event loop.

Instead of popping ONE earliest-ready warp per step (the exact event
engine), each step pops a *wave* of the ``wave_size`` earliest-ready
warps and services all their W×L requests vectorized. Because the wave is
selected by readiness, its requests are close together in simulated time,
which is what makes batched processing faithful. Each wave runs two
passes:

  1. **Cache pass**: bypass decisions, tag lookup, RRIP fill/eviction,
     EAF and PC-table bookkeeping, and the classifier update on
     wave-resident [B] counter rows. A lane sub-step carries at most ONE
     request per warp, so the batched observe is equivalent to the event
     loop's sequential per-request observes (warp ids are distinct —
     pinned by the differential suite). None of these outcomes depend on
     request *timing*, so the pass needs no queue state. Cross-slot
     structural conflicts inside one sub-step (two wave warps filling
     the same cache set) resolve last-write-wins in chronological slot
     order. The implementation lives in ``repro.kernels.cache_pass``
     behind a backend gate (``cache_backend``, mirroring the timing
     pass's ``scan_backend``): ``"ref"`` is the original per-lane
     ``lax.scan``, ``"fused"`` a bitwise-identical one-sweep
     reformulation that resolves same-set write conflicts with explicit
     per-set chronology pointers (the CPU default), ``"pallas"`` a
     lane-chunked TPU kernel. The lifetime counters and scalar metrics
     — never read during the wave — are hoisted out of the pass and
     applied once per wave for every backend (integer adds, so the
     totals are exact either way).

  2. **Timing pass**: all B×L requests of the wave, in warp-major
     chronological order (the event loop's pop-and-service order), go
     through segmented prefix queue recovery — ``start_j = c_j +
     max_{i<=j}(max(t_i, free) - c_i)`` with ``c`` the exclusive prefix
     occupancy of the request's queue (exactly the sequential FR-FCFS
     arrival-order service times). The implementation now lives in
     ``repro.kernels.wavefront_scan`` behind a backend gate
     (``scan_backend``): ``"ref"`` is the original unfused multi-pass
     form, ``"fused"`` a bitwise-identical slot-major reformulation with
     fast associative scans (the CPU default), ``"pallas"`` a one-pass
     TPU kernel. The DRAM row-buffer chain links each request to its
     true chronological predecessor in its channel, and the low-priority
     queue's floor folds in the running busy horizon of the wave's
     high-priority chain (strict priority, as in the event engine).
     Cross-wave carry uses the work-conserving backlog floor
     (``wavefront_scan.ref.carry_floor``).

The approximation ladder (DESIGN.md §9): event (wave of 1, exact) →
wavefront (wave of W/6, W/4 at stress populations — near-chronological;
the differential suite pins the envelope) → full round-lockstep
(``wave_size=n_warps`` — one scan step services an entire instruction
round). A wave of one warp reduces every prefix op to the event
engine's scalar update, so single-warp traces match the event path
exactly.

Cost: the wave loop is a ``lax.while_loop`` capped at ``ceil(I·W/B) +
I`` steps but exiting at the first wave with no active warp left: with
>= B warps active every wave services B instructions (<= ceil(I·W/B)
such waves), and once fewer than B remain every wave advances ALL of
them (<= I further waves) — the cap is only reached when warp
completion is maximally staggered, so typical runs take close to
ceil(I·W/B) steps instead of the cap (the seed-era scan always ran all
of them; a wave of inactive warps is a proven no-op, so early exit is
byte-identical). Each step does O(B)-vectorized work, vs the event
loop's O(I·W·L) sequential steps — this is what runs the 1k–4k-warp
stress matrix (tracegen/stress.py) end-to-end.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.engine import request as REQ
from repro.core.engine.state import SimParams, SimState, init_state
from repro.kernels.cache_pass import ops as CPASS
from repro.kernels.cache_pass.ref import observe_gathered, observe_vec
from repro.kernels.wavefront_scan import ops as WSCAN
from repro.kernels.wavefront_scan.ref import QueueCarry
from repro.policy import PolicyArrays, ops as POL

# the O(B) classifier-observe forms moved to repro.kernels.cache_pass.ref
# with the rest of the pass (PR 8); re-exported for their established
# import site (tests/test_engine_differential.py pins them against the
# full-width ``classifier.observe``)
_observe_gathered = observe_gathered
_observe_vec = observe_vec

F32 = jnp.float32
I32 = jnp.int32

_NEG = -jnp.inf


def _warp_constraint(mesh, axes, dim: int):
    """Sharding constraint placing mesh ``axes`` on dimension ``dim``
    (the warp axis) of an array; identity without a mesh. Composes with
    vmap — the batch rule inserts the vmapped dim as replicated, so the
    same constraint serves the policy/seed-vmapped sweep."""
    if mesh is None or axes is None:
        return lambda x: x

    def constrain(x):
        spec = [None] * x.ndim
        spec[dim] = axes
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, PartitionSpec(*spec)))
    return constrain


def _replicate_constraint(mesh):
    """Constraint gathering an array to full replication — applied to
    the per-warp state right before ``finalize_outputs`` so the final
    float reductions (e.g. the IPC sum over warps) run over a replicated
    array in the exact single-device order (bitwise parity)."""
    if mesh is None:
        return lambda x: x
    return lambda x: jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*([None] * x.ndim))))


def default_wave_size(n_warps: int) -> int:
    """Readiness-window size. W/6 keeps a wave chronologically tight
    (warp populations that drifted apart never share a wave); calibrated
    on the 15-workload × 4-policy differential matrix at the paper's 48
    warps (worst |IPC| deviation 1.9%, worst makespan deviation 2.1% —
    DESIGN.md §9). Above the differential-verified zone the stress
    populations are W/4-waved: thousands of statistically similar warps
    keep waves relatively tight, and the wider wave amortizes per-step
    cost further."""
    if n_warps > 256:
        return n_warps // 4
    return max(min(n_warps, 8), n_warps // 6)


class QueueAnchors(NamedTuple):
    """Per-queue service frontier, in two time axes.

    ``*_ts`` is the largest L2-arrival (wave sort) time the queue has
    serviced; ``*_sa`` the largest service-arrival time (equal to
    ``*_ts`` for banks, but DRAM requests arrive at ``t_head + l2_lat``
    after the L2 queue, so the axes differ there). Together with the
    queue's busy-until (``bank_free``/``hp_free``/``lp_free`` in
    SimState) they summarize the queue's backlog for the next wave:
    ``backlog = free - sa``.
    """
    bank_ts: jnp.ndarray     # f32[banks]
    hp_ts: jnp.ndarray       # f32[channels]
    hp_sa: jnp.ndarray       # f32[channels]
    lp_ts: jnp.ndarray       # f32[channels]
    lp_sa: jnp.ndarray       # f32[channels]


def init_anchors(prm: SimParams) -> QueueAnchors:
    c = jnp.full((prm.dram_channels,), _NEG, F32)
    return QueueAnchors(bank_ts=jnp.full((prm.banks,), _NEG, F32),
                        hp_ts=c, hp_sa=c, lp_ts=c, lp_sa=c)


def _timing_pass(st: SimState, an: QueueAnchors, recs, prm: SimParams,
                 backend: str) -> tuple:
    """Arrival-ordered queue recovery for one wave's B×L requests.

    Chronological bank/channel semantics come from segmented prefix
    queue recovery per L2 bank, DRAM channel and priority class over the
    wave's requests in WARP-MAJOR order — warp slots ascend in ready
    time (the wave selection) and a warp's lanes stay consecutive, which
    is exactly the event loop's processing order (pop the earliest warp,
    service all its lanes back-to-back). Interleaving by raw per-lane
    arrival instead would shred the DRAM row-buffer streaks a streaming
    warp's consecutive lines produce. The recovery itself is
    ``repro.kernels.wavefront_scan`` under the selected backend.
    """
    t_s, addr_s, valid_s, byp_s, use_l2_s, hit_s, hp_s = \
        [jnp.swapaxes(x, 0, 1).reshape(-1) for x in recs[:7]]  # [N = B*L]
    # a wave of ONE warp is the event loop — no batching to compensate
    # for, so the carry floor is the plain busy-until (bitwise parity
    # with engine="event", asserted by the differential suite)
    exact = recs[0].shape[1] == 1

    bank = REQ.bank_index(addr_s, prm)
    ch = REQ.dram_channel(addr_s, prm)
    row = REQ.dram_row(addr_s, prm)
    go_dram = valid_s & (byp_s | ~hit_s)

    carry = QueueCarry(
        bank_free=st.bank_free, bank_ts=an.bank_ts,
        hp_free=st.hp_free, hp_ts=an.hp_ts, hp_sa=an.hp_sa,
        lp_free=st.lp_free, lp_ts=an.lp_ts, lp_sa=an.lp_sa,
        cur_row=st.cur_row)
    t_head, t0, row_hit, nc = WSCAN.wave_queue_recovery(
        t_s, bank, use_l2_s, ch, row, go_dram, byp_s, hp_s, carry,
        banks=prm.banks, channels=prm.dram_channels, l2_svc=prm.l2_svc,
        l2_lat=prm.l2_lat, occ_rowhit=prm.occ_rowhit,
        occ_rowmiss=prm.occ_rowmiss, exact=exact, backend=backend)

    qdelay = jnp.where(use_l2_s, t_head - t_s, 0.0)
    _, lat = REQ.dram_occ_lat(row_hit, prm)
    t_done = jnp.where(hit_s, t_head + prm.l2_lat, t0 + lat)
    t_done = jnp.where(valid_s, t_done, t_s)

    # ---- metrics ------------------------------------------------------------
    m = st.metrics
    qbin = REQ.qdelay_bin(qdelay)
    metrics = dict(m)
    if WSCAN.resolve_backend(backend) != "ref":
        # one-hot histogram: integer adds in any order are exact, and
        # the dense [N, bins] reduce beats XLA:CPU's serialized
        # scatter-add by ~4x at stress-scale N (the ref backend keeps
        # the original scatter so the A/B baseline graph is unchanged)
        nb = m["qdelay_hist"].shape[0]
        oh = qbin[:, None] == jnp.arange(nb, dtype=I32)[None, :]
        metrics["qdelay_hist"] = m["qdelay_hist"] + jnp.sum(
            jnp.where(oh, use_l2_s[:, None].astype(I32), 0), axis=0)
    else:
        metrics["qdelay_hist"] = m["qdelay_hist"].at[qbin].add(
            use_l2_s.astype(I32))
    metrics["qdelay_sum"] = m["qdelay_sum"] + jnp.sum(qdelay)
    metrics["dram_accesses"] = m["dram_accesses"] + jnp.sum(
        go_dram.astype(I32))
    metrics["row_hits"] = m["row_hits"] + jnp.sum(row_hit.astype(I32))

    new_st = st._replace(bank_free=nc.bank_free, cur_row=nc.cur_row,
                         hp_free=nc.hp_free, lp_free=nc.lp_free,
                         metrics=metrics)
    new_an = QueueAnchors(bank_ts=nc.bank_ts, hp_ts=nc.hp_ts,
                          hp_sa=nc.hp_sa, lp_ts=nc.lp_ts, lp_sa=nc.lp_sa)
    # back to the cache pass's [L, B] layout
    lanes, b = recs[0].shape
    t_done_lb = jnp.swapaxes(t_done.reshape(b, lanes), 0, 1)
    return new_st, new_an, t_done_lb


def simulate_core(trace_lines, trace_pcs, compute_gap, oracle_types,
                  pa: PolicyArrays, *, n_warps: int, lanes: int,
                  prm: SimParams, wave_size: Optional[int] = None,
                  scan_backend: str = "auto",
                  cache_backend: str = "auto",
                  warp_mesh=None, warp_axes=None) -> Dict[str, Any]:
    """One workload × one policy on the wavefront engine. Vmappable.

    ``compute_gap`` is a scalar or f32[I]; ``oracle_types`` i32[I, W]
    (same contract as ``event.simulate_core``). ``scan_backend`` selects
    the timing-pass implementation (``wavefront_scan.BACKENDS``) and
    ``cache_backend`` the cache-pass one (``cache_pass.BACKENDS``):
    ``"ref"`` is the respective pre-fusion path kept as the unfused side
    of the in-run perf A/B; every other backend is output-identical to
    it (bitwise for ``"fused"``, the CPU default under ``"auto"``), so
    the two knobs compose freely.

    ``warp_mesh`` + ``warp_axes`` (both static, pre-resolved by the
    ``simulate``/``simulate_sweep`` front door) enable the sharded-warp
    path: the trace storage arrays ([W, I, L] — the memory that grows
    with the population) and the per-warp machine state (ready/ptr
    clocks, classifier rows, lifetime counters, the [I, W] ratio trace)
    are constrained to shard their warp axis over those mesh axes, so a
    16k–64k-warp stress spec spreads across the mesh instead of sitting
    on one device. The wave gathers/scatters cross shards (XLA inserts
    the collectives); the per-wave [B]-sized compute is replicated, and
    the state is gathered back to full replication before
    ``finalize_outputs`` so the closing float reductions keep the exact
    single-device operand order — the whole path is bitwise-identical
    to the unsharded engine (pinned by tests/test_sharded_sweep.py)."""
    n_instr = trace_lines.shape[0]
    shard_w0 = _warp_constraint(warp_mesh, warp_axes, 0)
    shard_w1 = _warp_constraint(warp_mesh, warp_axes, 1)
    B = max(1, min(wave_size or default_wave_size(n_warps), n_warps))
    # wave-count CAP (the while_loop usually exits earlier, see module
    # docstring): phase 1 (>= B warps active) services B instructions
    # per wave; once fewer than B warps remain every wave advances all
    # of them, so at most n_instr further waves finish the tail
    n_waves = -(-n_instr * n_warps // B) + n_instr
    # the stable-argsort wave selection only survives in the all-ref
    # baseline graph; any fused backend takes the top_k form (bitwise
    # tie-parity between the two is pinned by the differential suite)
    fused = (WSCAN.resolve_backend(scan_backend) != "ref"
             or CPASS.resolve_backend(cache_backend) != "ref")
    tokens = POL.pcal_tokens(pa, n_warps)

    lines_wi = shard_w0(jnp.swapaxes(trace_lines, 0, 1))  # [W, I, L]
    pcs_wi = shard_w0(jnp.swapaxes(trace_pcs, 0, 1))      # [W, I]
    oracle_wi = shard_w0(jnp.swapaxes(oracle_types, 0, 1))  # [W, I]

    st0 = init_state(n_warps, prm)
    st0 = st0._replace(clf=jax.tree.map(shard_w0, st0.clf),
                       tot_hits=shard_w0(st0.tot_hits),
                       tot_acc=shard_w0(st0.tot_acc))
    an0 = init_anchors(prm)
    ready0 = shard_w0(jnp.zeros((n_warps,), F32))
    ptr0 = shard_w0(jnp.zeros((n_warps,), I32))
    ratio0 = shard_w1(jnp.zeros((n_instr, n_warps), F32))

    def wave_step(carry):
        st, an, ready, ptr, ratio_t, k = carry
        active = ptr < n_instr
        # wave = the B earliest-ready active warps, slots in
        # chronological order, ties by warp id (the event loop's
        # argmin). top_k on the negated keys returns exactly the first
        # B entries of the stable ascending argsort (equal keys by
        # lower index) at O(W log B) instead of the full O(W log W)
        # sort — tie-parity is pinned by the differential suite.
        if fused:
            w_sel = jax.lax.top_k(
                jnp.where(active, -ready, _NEG), B)[1].astype(I32)
        else:
            order = jnp.argsort(jnp.where(active, ready, jnp.inf))
            w_sel = order[:B].astype(I32)
        slot_ok = active[w_sel]
        i_sel = ptr[w_sel]
        t0 = ready[w_sel]
        lines_b = lines_wi[w_sel, i_sel]             # [B, L]
        pc_b = pcs_wi[w_sel, i_sel]                  # [B]
        owt_b = oracle_wi[w_sel, i_sel]              # [B]

        # wave-resident classifier rows: gather once, carry [B] slices
        # through the pass, scatter back once (wave warp ids are
        # distinct, so nothing else touches the rows mid-wave — see
        # cache_pass.ref.observe_vec)
        clf_b0 = jax.tree.map(lambda a: a[w_sel], st.clf)
        tokens_b = tokens[w_sel]
        st, clf_b, recs = CPASS.wave_cache_pass(
            st, clf_b0, tokens_b, t0, jnp.swapaxes(lines_b, 0, 1), pc_b,
            owt_b, slot_ok, prm, pa, backend=cache_backend)
        st = st._replace(clf=jax.tree.map(
            lambda full, b: full.at[w_sel].set(b), st.clf, clf_b))
        st, an, t_done = _timing_pass(st, an, recs, prm, scan_backend)

        (_, _, valid_lb, byp_lb, use_lb, hit_lb, _, vt_lb, ev_lb) = recs
        # hoisted write-only bookkeeping: one update per wave instead of
        # one per lane (integer adds — exact either way)
        m = st.metrics
        metrics = dict(m)
        metrics["l2_accesses"] = m["l2_accesses"] + jnp.sum(
            use_lb.astype(I32))
        metrics["l2_hits"] = m["l2_hits"] + jnp.sum(hit_lb.astype(I32))
        metrics["bypasses"] = m["bypasses"] + jnp.sum(
            byp_lb.astype(I32))
        # one-hot over the type bins (victim_type is always a written
        # label, in range) instead of an [N] scatter-add, which XLA:CPU
        # serializes per element
        n_types = m["evictions_by_type"].shape[0]
        vt_oh = vt_lb.reshape(-1)[:, None] \
            == jnp.arange(n_types, dtype=I32)[None, :]
        metrics["evictions_by_type"] = m["evictions_by_type"] + jnp.sum(
            jnp.where(vt_oh, ev_lb.reshape(-1)[:, None].astype(I32), 0),
            axis=0)
        st = st._replace(
            tot_hits=st.tot_hits.at[w_sel].add(
                jnp.sum(hit_lb.astype(I32), axis=0)),
            tot_acc=st.tot_acc.at[w_sel].add(
                jnp.sum(valid_lb.astype(I32), axis=0)),
            metrics=metrics)

        dmax = jnp.max(jnp.where(valid_lb, t_done, -jnp.inf), axis=0)
        dmin = jnp.min(jnp.where(valid_lb, t_done, jnp.inf), axis=0)
        has_req = jnp.isfinite(dmax)
        stall = jnp.where(has_req & slot_ok, dmax - dmin, 0.0)
        metrics = dict(st.metrics)
        metrics["stall_cycles"] = metrics["stall_cycles"] + jnp.sum(stall)
        st = st._replace(metrics=metrics)

        w_ok = jnp.where(slot_ok, w_sel, n_warps)    # OOB -> dropped
        gap = compute_gap if jnp.ndim(compute_gap) == 0 \
            else compute_gap[i_sel]
        ready = ready.at[w_ok].set(
            jnp.where(has_req, dmax + gap, t0 + gap),
            mode="drop")
        ptr = ptr.at[w_ok].add(1, mode="drop")
        # Fig 4 snapshot: sampled ratio after each serviced instruction
        ratio_t = ratio_t.at[i_sel, w_ok].set(st.clf.ratio[w_sel],
                                              mode="drop")
        # pin the loop-carried warp-axis sharding (no-ops unsharded):
        # without the constraint GSPMD may resolve the scattered-into
        # carries to a different layout each iteration
        st = st._replace(clf=jax.tree.map(shard_w0, st.clf),
                         tot_hits=shard_w0(st.tot_hits),
                         tot_acc=shard_w0(st.tot_acc))
        return (st, an, shard_w0(ready), shard_w0(ptr),
                shard_w1(ratio_t), k + 1)

    def wave_pending(carry):
        _, _, _, ptr, _, k = carry
        return (k < n_waves) & jnp.any(ptr < n_instr)

    (st, _, ready, _, ratio_t, _) = jax.lax.while_loop(
        wave_pending, wave_step,
        (st0, an0, ready0, ptr0, ratio0, jnp.zeros((), I32)))

    # gather the per-warp state back to replication before the closing
    # reductions — jnp.sum over a sharded axis would reduce shard-local
    # partials first, changing the float accumulation order vs the
    # single-device engine
    rep = _replicate_constraint(warp_mesh)
    st = st._replace(clf=jax.tree.map(rep, st.clf),
                     tot_hits=rep(st.tot_hits), tot_acc=rep(st.tot_acc))
    return REQ.finalize_outputs(st, rep(ready), rep(ratio_t), compute_gap,
                                n_instr=n_instr, n_warps=n_warps, prm=prm)
