"""Simulation state and static configuration shared by both engines.

``SimParams`` is the hardware description (frozen dataclass — a static
jit argument), ``SimState`` the mutable machine state threaded through
either event loop, and ``init_state`` the common initial condition.
Everything here is engine-agnostic: the exact discrete-event loop
(``engine/event.py``) and the round-lockstep wavefront loop
(``engine/wavefront.py``) both start from the same state and mutate the
same fields.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple

import jax.numpy as jnp

from repro.core import classifier as CLF
from repro.core import warp_types as WT

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class SimParams:
    sets: int = 512
    ways: int = 8
    banks: int = 6
    l2_svc: float = 4.0        # bank occupancy per request (cycles)
    l2_lat: float = 20.0       # tag+data latency after reaching bank head
    dram_channels: int = 8
    row_lines: int = 32        # lines per DRAM row
    # occupancy (pipelined throughput) vs latency (critical path) split
    occ_rowhit: float = 5.0
    occ_rowmiss: float = 10.0
    t_rowhit: float = 100.0
    t_rowmiss: float = 200.0
    lane_skew: float = 0.5     # per-lane issue skew within an instruction
    rrip_max: int = 7
    eaf_bits: int = 4096
    eaf_capacity: int = 1024   # filter reset period (insertions)
    pc_entries: int = 256
    sampling_interval: int = 64
    # classifier probe cadence: every Nth access of a bypassing warp is
    # forced down the cache path (the default when the policy's traced
    # ``PolicyArrays.probe_interval`` is 0 — see ``POL.probe_interval``)
    probe_interval: int = 8
    mostly_hit_threshold: float = 0.8
    mostly_miss_threshold: float = 0.2
    # energy model (relative units, GPUWattch-flavoured)
    e_l2: float = 1.0
    e_dram: float = 12.0
    e_static: float = 0.08     # per cycle of makespan


class SimState(NamedTuple):
    tags: jnp.ndarray          # i32[sets, ways] line addr or -1
    rrip: jnp.ndarray          # i32[sets, ways]
    meta_type: jnp.ndarray     # i32[sets, ways] inserting warp's type
    bank_free: jnp.ndarray     # f32[banks]
    cur_row: jnp.ndarray       # i32[channels]
    hp_free: jnp.ndarray       # f32[channels]
    lp_free: jnp.ndarray       # f32[channels]
    clf: CLF.ClassifierState
    eaf: jnp.ndarray           # i32[eaf_bits] generation-stamped bloom bits
    eaf_gen: jnp.ndarray       # i32[] current generation: a bit is set iff
    #                            eaf[i] == eaf_gen, so the periodic filter
    #                            reset is a generation bump, not a (costly
    #                            per-step) array clear
    eaf_ctr: jnp.ndarray       # i32[] insertions since reset
    pc_hits: jnp.ndarray       # i32[pc_entries] cache-path hits
    pc_acc: jnp.ndarray        # i32[pc_entries] cache-path accesses
    pc_req: jnp.ndarray        # i32[pc_entries] ALL valid requests — the
    #                            PC-probe cadence clock. pc_acc freezes
    #                            while a PC bypasses, so gating the probe
    #                            on it would never fire again (the PR 7
    #                            ratchet audit); pc_req keeps ticking.
    tot_hits: jnp.ndarray      # i32[W] lifetime counters (never reset)
    tot_acc: jnp.ndarray       # i32[W]
    metrics: Dict[str, jnp.ndarray]


_QBINS = jnp.asarray([0, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 1 << 30],
                     jnp.float32)
N_QBINS = len(_QBINS) - 1      # one bin per [edge_i, edge_{i+1}) interval


def init_state(n_warps: int, prm: SimParams) -> SimState:
    metrics = {
        "qdelay_hist": jnp.zeros((N_QBINS,), I32),
        "qdelay_sum": jnp.zeros((), F32),
        "l2_accesses": jnp.zeros((), I32),
        "l2_hits": jnp.zeros((), I32),
        "dram_accesses": jnp.zeros((), I32),
        "row_hits": jnp.zeros((), I32),
        "bypasses": jnp.zeros((), I32),
        "stall_cycles": jnp.zeros((), F32),
        "evictions_by_type": jnp.zeros((WT.NUM_TYPES,), I32),
    }
    return SimState(
        tags=jnp.full((prm.sets, prm.ways), -1, I32),
        rrip=jnp.full((prm.sets, prm.ways), prm.rrip_max, I32),
        meta_type=jnp.full((prm.sets, prm.ways), WT.BALANCED, I32),
        bank_free=jnp.zeros((prm.banks,), F32),
        cur_row=jnp.full((prm.dram_channels,), -1, I32),
        hp_free=jnp.zeros((prm.dram_channels,), F32),
        lp_free=jnp.zeros((prm.dram_channels,), F32),
        clf=CLF.init(n_warps),
        eaf=jnp.zeros((prm.eaf_bits,), I32),
        eaf_gen=jnp.ones((), I32),
        eaf_ctr=jnp.zeros((), I32),
        pc_hits=jnp.zeros((prm.pc_entries,), I32),
        pc_acc=jnp.zeros((prm.pc_entries,), I32),
        pc_req=jnp.zeros((prm.pc_entries,), I32),
        tot_hits=jnp.zeros((n_warps,), I32),
        tot_acc=jnp.zeros((n_warps,), I32),
        metrics=metrics,
    )
