"""Altitude-A faithful MeDiC simulator (paper §3, evaluated as §5).

A request-level discrete-event model of the GPU shared memory hierarchy,
implemented as pure JAX (`lax.scan` over rounds × chronologically sorted
requests) so a full policy sweep runs jitted on CPU.

Modelled structures (paper's evaluation fidelity, not RTL):
  * warps in lockstep: a memory instruction issues `lanes` coalesced line
    requests; the warp is ready for its next instruction only when the
    SLOWEST request completes (memory divergence);
  * banked, set-associative shared L2 with per-bank service queues (queuing
    delay emerges from bank next-free-time counters — observation O3);
  * RRIP-style replacement whose insertion rank the policy controls (③);
  * DRAM channels with open-row buffers and a TWO-QUEUE FR-FCFS scheduler:
    high-priority queue strictly bypasses the low-priority one (④);
  * warp-type identification via per-warp hit/access counters (①) and
    warp-type-aware bypassing straight to the DRAM queue (②).

Policy decisions go through the branchless `repro.policy` engine: the
policy enters the jitted computation as a *traced* `PolicyArrays` pytree,
so every policy shares ONE trace per workload shape, and `simulate_sweep`
vmaps a stacked policy batch (optionally × seed-stacked traces) in a
single jitted call — the whole Fig 7/8 sweep compiles once and runs
batched (DESIGN.md §3).

Approximation (recorded in DESIGN.md §8): requests are processed
chronologically *within* an instruction round but rounds are processed in
lockstep across warps, so far-ahead warps can observe slightly stale queue
state. All policies share the machinery, so comparisons are like-for-like.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import classifier as CLF
from repro.core import warp_types as WT
from repro.policy import Policy, PolicyArrays, ops as POL
from repro.policy import stack_policies, to_arrays

F32 = jnp.float32
I32 = jnp.int32

_hash = POL.hash_index


# ---------------------------------------------------------------------------
# static configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimParams:
    sets: int = 512
    ways: int = 8
    banks: int = 6
    l2_svc: float = 4.0        # bank occupancy per request (cycles)
    l2_lat: float = 20.0       # tag+data latency after reaching bank head
    dram_channels: int = 8
    row_lines: int = 32        # lines per DRAM row
    # occupancy (pipelined throughput) vs latency (critical path) split
    occ_rowhit: float = 5.0
    occ_rowmiss: float = 10.0
    t_rowhit: float = 100.0
    t_rowmiss: float = 200.0
    lane_skew: float = 0.5     # per-lane issue skew within an instruction
    rrip_max: int = 7
    eaf_bits: int = 4096
    eaf_capacity: int = 1024   # filter reset period (insertions)
    pc_entries: int = 256
    sampling_interval: int = 64
    mostly_hit_threshold: float = 0.8
    mostly_miss_threshold: float = 0.2
    # energy model (relative units, GPUWattch-flavoured)
    e_l2: float = 1.0
    e_dram: float = 12.0
    e_static: float = 0.08     # per cycle of makespan


class SimState(NamedTuple):
    tags: jnp.ndarray          # i32[sets, ways] line addr or -1
    rrip: jnp.ndarray          # i32[sets, ways]
    meta_type: jnp.ndarray     # i32[sets, ways] inserting warp's type
    bank_free: jnp.ndarray     # f32[banks]
    cur_row: jnp.ndarray       # i32[channels]
    hp_free: jnp.ndarray       # f32[channels]
    lp_free: jnp.ndarray       # f32[channels]
    clf: CLF.ClassifierState
    eaf: jnp.ndarray           # i32[eaf_bits] bloom bits
    eaf_ctr: jnp.ndarray       # i32[] insertions since reset
    pc_hits: jnp.ndarray       # i32[pc_entries]
    pc_acc: jnp.ndarray        # i32[pc_entries]
    tot_hits: jnp.ndarray      # i32[W] lifetime counters (never reset)
    tot_acc: jnp.ndarray       # i32[W]
    metrics: Dict[str, jnp.ndarray]


_QBINS = jnp.asarray([0, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 1 << 30],
                     jnp.float32)
N_QBINS = 12


def init_state(n_warps: int, prm: SimParams) -> SimState:
    metrics = {
        "qdelay_hist": jnp.zeros((N_QBINS,), I32),
        "qdelay_sum": jnp.zeros((), F32),
        "l2_accesses": jnp.zeros((), I32),
        "l2_hits": jnp.zeros((), I32),
        "dram_accesses": jnp.zeros((), I32),
        "row_hits": jnp.zeros((), I32),
        "bypasses": jnp.zeros((), I32),
        "stall_cycles": jnp.zeros((), F32),
        "evictions_by_type": jnp.zeros((WT.NUM_TYPES,), I32),
    }
    return SimState(
        tags=jnp.full((prm.sets, prm.ways), -1, I32),
        rrip=jnp.full((prm.sets, prm.ways), prm.rrip_max, I32),
        meta_type=jnp.full((prm.sets, prm.ways), WT.BALANCED, I32),
        bank_free=jnp.zeros((prm.banks,), F32),
        cur_row=jnp.full((prm.dram_channels,), -1, I32),
        hp_free=jnp.zeros((prm.dram_channels,), F32),
        lp_free=jnp.zeros((prm.dram_channels,), F32),
        clf=CLF.init(n_warps),
        eaf=jnp.zeros((prm.eaf_bits,), I32),
        eaf_ctr=jnp.zeros((), I32),
        pc_hits=jnp.zeros((prm.pc_entries,), I32),
        pc_acc=jnp.zeros((prm.pc_entries,), I32),
        tot_hits=jnp.zeros((n_warps,), I32),
        tot_acc=jnp.zeros((n_warps,), I32),
        metrics=metrics,
    )


# ---------------------------------------------------------------------------
# one request
# ---------------------------------------------------------------------------

def _request_step(st: SimState, req, prm: SimParams, pa: PolicyArrays,
                  tokens) -> tuple:
    t_arr, w, addr, pc, valid = req
    m = st.metrics
    wtype = st.clf.warp_type[w]
    pidx = _hash(pc, 3, prm.pc_entries)

    # ---- ② bypass decision (branchless, repro.policy) ----------------------
    # periodic probe so a reformed warp can be re-learned: every 8th access
    # of a bypassing warp still takes the cache path
    probe = (st.clf.accesses[w] % 8) == 0
    rand_u = _hash(addr, 7, 65536).astype(F32) / 65536.0
    byp = POL.bypass_decision(pa, wtype=wtype, probe=probe,
                              token_bit=tokens[w],
                              pc_hits=st.pc_hits[pidx],
                              pc_acc=st.pc_acc[pidx], rand_u=rand_u)
    byp = byp & valid

    use_l2 = valid & ~byp

    # ---- L2 bank queue (O3) ------------------------------------------------
    bank = _hash(addr, 1, prm.banks)
    t_head = jnp.maximum(st.bank_free[bank], t_arr)
    bank_free = st.bank_free.at[bank].set(
        jnp.where(use_l2, t_head + prm.l2_svc, st.bank_free[bank]))
    qdelay = jnp.where(use_l2, t_head - t_arr, 0.0)

    # ---- L2 lookup ----------------------------------------------------------
    sidx = _hash(addr, 2, prm.sets)
    tset = st.tags[sidx]
    is_line = tset == addr
    hit = jnp.any(is_line) & use_l2
    hit_way = jnp.argmax(is_line)
    rset = st.rrip[sidx]
    rset = jnp.where(hit, rset.at[hit_way].set(0), rset)

    # ---- ③ fill + insertion (branchless, repro.policy) ---------------------
    allocate = use_l2 & ~hit
    # SRRIP aging to make a victim available
    shift = prm.rrip_max - jnp.max(rset)
    rset_aged = rset + jnp.where(allocate, shift, 0)
    victim = jnp.argmax(rset_aged)
    evicted = tset[victim]

    ebit = st.eaf[_hash(addr, 5, prm.eaf_bits)] > 0
    rank = POL.insertion_rank(pa, wtype=wtype, eaf_bit=ebit,
                              rrip_max=prm.rrip_max)

    tags = st.tags.at[sidx, victim].set(jnp.where(allocate, addr, evicted))
    rrip = st.rrip.at[sidx].set(
        jnp.where(allocate, rset_aged.at[victim].set(rank), rset))
    meta_type = st.meta_type.at[sidx, victim].set(
        jnp.where(allocate, wtype, st.meta_type[sidx, victim]))

    # EAF bookkeeping: remember evicted addresses; periodic reset
    ev_valid = allocate & (evicted >= 0)
    eaf = st.eaf.at[_hash(evicted, 5, prm.eaf_bits)].set(
        jnp.where(ev_valid, 1, st.eaf[_hash(evicted, 5, prm.eaf_bits)]))
    eaf_ctr = st.eaf_ctr + ev_valid.astype(I32)
    reset = eaf_ctr >= prm.eaf_capacity
    eaf = jnp.where(reset, jnp.zeros_like(eaf), eaf)
    eaf_ctr = jnp.where(reset, 0, eaf_ctr)

    # ---- ④ DRAM two-queue FR-FCFS (branchless, repro.policy) ---------------
    go_dram = valid & (byp | ~hit)
    t_dram_arr = jnp.where(byp, t_arr, t_head + prm.l2_lat)
    ch = _hash(addr // prm.row_lines, 4, prm.dram_channels)
    row = (addr // prm.row_lines).astype(I32)
    row_hit = (st.cur_row[ch] == row) & go_dram
    occ = jnp.where(row_hit, prm.occ_rowhit, prm.occ_rowmiss)
    lat = jnp.where(row_hit, prm.t_rowhit, prm.t_rowmiss)
    hp = POL.is_high_priority(pa, wtype)
    t0_hp = jnp.maximum(st.hp_free[ch], t_dram_arr)
    t0_lp = jnp.maximum(jnp.maximum(st.lp_free[ch], st.hp_free[ch]),
                        t_dram_arr)
    t0 = jnp.where(hp, t0_hp, t0_lp)
    hp_free = st.hp_free.at[ch].set(
        jnp.where(go_dram & hp, t0 + occ, st.hp_free[ch]))
    lp_free = st.lp_free.at[ch].set(
        jnp.where(go_dram & ~hp, t0 + occ, st.lp_free[ch]))
    cur_row = st.cur_row.at[ch].set(jnp.where(go_dram, row, st.cur_row[ch]))
    t_done_dram = t0 + lat

    t_done = jnp.where(hit, t_head + prm.l2_lat, t_done_dram)
    t_done = jnp.where(valid, t_done, t_arr)

    # ---- ① classifier + PC table + lifetime counters ------------------------
    clf = CLF.observe(st.clf, w, hit,
                      sampling_interval=prm.sampling_interval,
                      mostly_hit_threshold=prm.mostly_hit_threshold,
                      mostly_miss_threshold=prm.mostly_miss_threshold,
                      weight=jnp.atleast_1d(valid.astype(I32)))
    pc_hits = st.pc_hits.at[pidx].add((hit & use_l2).astype(I32))
    pc_acc = st.pc_acc.at[pidx].add(use_l2.astype(I32))
    tot_hits = st.tot_hits.at[w].add(hit.astype(I32))
    tot_acc = st.tot_acc.at[w].add(valid.astype(I32))

    # ---- metrics -------------------------------------------------------------
    qbin = jnp.sum(qdelay >= _QBINS[1:-1]).astype(I32)
    metrics = dict(m)
    metrics["qdelay_hist"] = m["qdelay_hist"].at[qbin].add(use_l2.astype(I32))
    metrics["qdelay_sum"] = m["qdelay_sum"] + qdelay
    metrics["l2_accesses"] = m["l2_accesses"] + use_l2.astype(I32)
    metrics["l2_hits"] = m["l2_hits"] + hit.astype(I32)
    metrics["dram_accesses"] = m["dram_accesses"] + go_dram.astype(I32)
    metrics["row_hits"] = m["row_hits"] + row_hit.astype(I32)
    metrics["bypasses"] = m["bypasses"] + byp.astype(I32)
    metrics["evictions_by_type"] = m["evictions_by_type"].at[
        st.meta_type[sidx, victim]].add(ev_valid.astype(I32))

    new_st = SimState(tags, rrip, meta_type, bank_free, cur_row, hp_free,
                      lp_free, clf, eaf, eaf_ctr, pc_hits, pc_acc,
                      tot_hits, tot_acc, metrics)
    return new_st, t_done


# ---------------------------------------------------------------------------
# full simulation
# ---------------------------------------------------------------------------

def _simulate_core(trace_lines, trace_pcs, compute_gap, pa: PolicyArrays,
                   *, n_warps: int, lanes: int,
                   prm: SimParams) -> Dict[str, Any]:
    """One workload × one policy. `pa` is a traced pytree — vmappable."""
    n_instr = trace_lines.shape[0]
    tokens = POL.pcal_tokens(pa, n_warps)

    # [W, I, ...] layout for per-warp program counters
    lines_wi = jnp.swapaxes(trace_lines, 0, 1)
    pcs_wi = jnp.swapaxes(trace_pcs, 0, 1)

    st0 = init_state(n_warps, prm)
    ready0 = jnp.zeros((n_warps,), F32)
    ptr0 = jnp.zeros((n_warps,), I32)

    def event_step(carry, _):
        st, ready, ptr = carry
        active = ptr < n_instr
        w = jnp.argmin(jnp.where(active, ready, jnp.inf)).astype(I32)
        i = ptr[w]
        lines = lines_wi[w, i]                        # [L]
        pc = pcs_wi[w, i]
        t0 = ready[w]
        lanes_idx = jnp.arange(lanes, dtype=I32)
        t_arr = t0 + lanes_idx.astype(F32) * prm.lane_skew
        valid = lines >= 0

        def body(s, r):
            return _request_step(s, r, prm, pa, tokens)

        reqs = (t_arr, jnp.full((lanes,), w, I32), lines,
                jnp.full((lanes,), pc, I32), valid)
        st, dones = jax.lax.scan(body, st, reqs)
        dmax = jnp.max(jnp.where(valid, dones, -jnp.inf))
        dmin = jnp.min(jnp.where(valid, dones, jnp.inf))
        has_req = jnp.isfinite(dmax)
        stall = jnp.where(has_req, dmax - dmin, 0.0)
        metrics = dict(st.metrics)
        metrics["stall_cycles"] = metrics["stall_cycles"] + stall
        st = st._replace(metrics=metrics)
        new_ready = ready.at[w].set(
            jnp.where(has_req, dmax + compute_gap, t0 + compute_gap))
        new_ptr = ptr.at[w].add(1)
        # snapshot for Fig 4: (warp, instr index, sampled ratio)
        snap = (w, i, st.clf.ratio[w])
        return (st, new_ready, new_ptr), snap

    (st, ready, _), snaps = jax.lax.scan(
        event_step, (st0, ready0, ptr0), None, length=n_instr * n_warps)

    # scatter snapshots into a [I, W] ratio-over-time matrix
    sw, si, sr = snaps
    ratio_t = jnp.zeros((n_instr, n_warps), F32).at[si, sw].set(sr)

    makespan = jnp.max(ready)
    m = dict(st.metrics)
    total_instr = jnp.asarray(n_instr * n_warps, F32)
    # System throughput in a steady state where finished warps' slots are
    # backfilled by fresh thread blocks (as on a real GPU): the sum of
    # per-warp progress rates. makespan-based IPC is also reported.
    per_warp_time = jnp.maximum(ready - compute_gap, 1.0)
    ipc = jnp.sum(n_instr / per_warp_time)
    ipc_makespan = total_instr / jnp.maximum(makespan, 1.0)
    energy = (m["l2_accesses"] * prm.e_l2 + m["dram_accesses"] * prm.e_dram
              + makespan * prm.e_static)
    out = dict(m)
    out.update({
        "makespan": makespan,
        "ipc": ipc,
        "ipc_makespan": ipc_makespan,
        "warp_time": per_warp_time,
        "energy": energy,
        "perf_per_energy": ipc / energy * 1e3,
        "warp_hit_ratio": st.tot_hits / jnp.maximum(st.tot_acc, 1),
        "warp_type": st.clf.warp_type,
        "ratio_over_time": ratio_t,            # [I, W]
        "miss_rate": 1.0 - m["l2_hits"] / jnp.maximum(m["l2_accesses"], 1),
        "mean_qdelay": m["qdelay_sum"] / jnp.maximum(m["l2_accesses"], 1),
    })
    return out


@partial(jax.jit, static_argnames=("prm", "n_warps", "lanes"))
def _simulate_one(trace_lines, trace_pcs, compute_gap, pa, *, n_warps: int,
                  lanes: int, prm: SimParams) -> Dict[str, Any]:
    return _simulate_core(trace_lines, trace_pcs, compute_gap, pa,
                          n_warps=n_warps, lanes=lanes, prm=prm)


@partial(jax.jit, static_argnames=("prm", "n_warps", "lanes"))
def _simulate_batch(trace_lines, trace_pcs, compute_gap, pa_batch, *,
                    n_warps: int, lanes: int, prm: SimParams):
    one = partial(_simulate_core, n_warps=n_warps, lanes=lanes, prm=prm)
    if trace_lines.ndim == 4:      # seed-stacked traces [S, I, W, L]
        over_seeds = jax.vmap(one, in_axes=(0, 0, 0, None))
        return jax.vmap(over_seeds, in_axes=(None, None, None, 0))(
            trace_lines, trace_pcs, compute_gap, pa_batch)
    return jax.vmap(one, in_axes=(None, None, None, 0))(
        trace_lines, trace_pcs, compute_gap, pa_batch)


def simulate(trace_lines, trace_pcs, compute_gap, *, n_warps: int,
             lanes: int, prm: SimParams, pol: Policy) -> Dict[str, Any]:
    """Run one workload under one policy.

    True discrete-event order: each outer step pops the globally earliest
    ready warp and services its next memory instruction, so queue counters
    are updated chronologically (up to intra-instruction lane skew).

    The policy enters as a traced `PolicyArrays`, so every `Policy` preset
    reuses the same compiled executable for a given workload shape.

    trace_lines: i32[I, W, L]; trace_pcs: i32[I, W].
    Returns metrics dict (all jnp arrays).
    """
    return _simulate_one(trace_lines, trace_pcs, compute_gap,
                         to_arrays(pol), n_warps=n_warps, lanes=lanes,
                         prm=prm)


def simulate_sweep(trace_lines, trace_pcs, compute_gap,
                   policies: Sequence[Policy], *, n_warps: int, lanes: int,
                   prm: SimParams) -> Dict[str, Any]:
    """Run a whole policy sweep in ONE jitted, vmapped call.

    trace_lines may be [I, W, L] (one workload instance — outputs get a
    leading policy axis P) or seed-stacked [S, I, W, L] (outputs get
    leading axes [P, S]); trace_pcs/compute_gap follow suit.

    Metrics match per-policy `simulate` calls bit-for-bit (the parity is
    enforced by tests/test_policy_engine.py).
    """
    pa = stack_policies(policies)
    return _simulate_batch(trace_lines, trace_pcs, compute_gap, pa,
                           n_warps=n_warps, lanes=lanes, prm=prm)
