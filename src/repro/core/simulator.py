"""Altitude-A faithful MeDiC simulator (paper §3, evaluated as §5) —
facade over the ``repro.core.engine`` subsystem.

A request-level discrete-event model of the GPU shared memory hierarchy,
implemented as pure JAX so a full policy sweep runs jitted on CPU.

Modelled structures (paper's evaluation fidelity, not RTL):
  * warps in lockstep: a memory instruction issues `lanes` coalesced line
    requests; the warp is ready for its next instruction only when the
    SLOWEST request completes (memory divergence);
  * banked, set-associative shared L2 with per-bank service queues (queuing
    delay emerges from bank next-free-time counters — observation O3);
  * RRIP-style replacement whose insertion rank the policy controls (③);
  * DRAM channels with open-row buffers and a TWO-QUEUE FR-FCFS scheduler:
    high-priority queue strictly bypasses the low-priority one (④);
  * warp-type identification via per-warp hit/access counters (①) and
    warp-type-aware bypassing straight to the DRAM queue (②).

Policy decisions go through the branchless `repro.policy` engine: the
policy enters the jitted computation as a *traced* `PolicyArrays` pytree,
so every policy shares ONE trace per workload shape, and `simulate_sweep`
vmaps a stacked policy batch (optionally × seed-stacked traces) in a
single jitted call (DESIGN.md §3).

Two engines share the state and per-request math (DESIGN.md §9):
``engine="event"`` (default) is the exact chronological discrete-event
loop; ``engine="wavefront"`` is the batched round-lockstep event loop
that services waves of earliest-ready warps vectorized — the path that
runs the tracegen stress matrix (1k–4k warps) end-to-end.

This module re-exports the public API for backward compatibility; the
implementation lives in ``repro/core/engine/``.
"""
from __future__ import annotations

from repro.core.engine import (ENGINES, N_QBINS, SimParams, SimState,
                               init_state, simulate, simulate_sweep,
                               _simulate_batch, _simulate_one)
from repro.core.engine.event import _request_step, simulate_core \
    as _simulate_core
from repro.policy import Policy, PolicyArrays

__all__ = [
    "ENGINES", "N_QBINS", "Policy", "PolicyArrays", "SimParams",
    "SimState", "init_state", "simulate", "simulate_sweep",
]
