"""Online warp-type identification (paper §3.1, mechanism ①).

Hardware model: two counters per warp (hits, accesses) incremented at the
shared cache, sampled every ``sampling_interval`` accesses; at each sampling
boundary the warp's type is re-evaluated from the observed hit ratio and the
counters reset. Between boundaries the warp keeps its last classification
(paper observation O2: divergence behaviour is stable over long periods).

Bypassed requests are counted as *misses* (they would have been: the warp
was classified mostly/all-miss). To let a reformed warp escape the bypass
class, a small fraction of bypassed requests is still probed through the
cache lookup path (``probe_interval``), mirroring the paper's periodic
resampling discussion.

Everything is functional and vectorized over warps so both the altitude-A
simulator and the altitude-B serving pool manager use the same code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import warp_types as WT


class ClassifierState(NamedTuple):
    hits: jnp.ndarray        # i32[W] hits in current sampling window
    accesses: jnp.ndarray    # i32[W] accesses in current sampling window
    warp_type: jnp.ndarray   # i32[W] current classification
    ratio: jnp.ndarray       # f32[W] last sampled hit ratio


def init(n_warps: int) -> ClassifierState:
    return ClassifierState(
        hits=jnp.zeros((n_warps,), jnp.int32),
        accesses=jnp.zeros((n_warps,), jnp.int32),
        warp_type=jnp.full((n_warps,), WT.BALANCED, jnp.int32),
        ratio=jnp.full((n_warps,), 0.5, jnp.float32),
    )


def observe(state: ClassifierState, warp_id, is_hit, *,
            sampling_interval: int = 256,
            mostly_hit_threshold: float = 0.8,
            mostly_miss_threshold: float = 0.2,
            weight=None) -> ClassifierState:
    """Record one (or a batch of) access outcome(s) and re-classify any warp
    whose sampling window filled up.

    warp_id: i32[] or i32[N]; is_hit: bool same shape.
    """
    warp_id = jnp.atleast_1d(warp_id)
    is_hit = jnp.atleast_1d(is_hit).astype(jnp.int32)
    if weight is None:
        weight = jnp.ones_like(is_hit)
    hits = state.hits.at[warp_id].add(is_hit * weight)
    accesses = state.accesses.at[warp_id].add(weight)

    due = accesses >= sampling_interval
    ratio_now = hits.astype(jnp.float32) / jnp.maximum(accesses, 1)
    new_type = WT.classify(ratio_now, accesses,
                           mostly_hit_threshold=mostly_hit_threshold,
                           mostly_miss_threshold=mostly_miss_threshold)
    warp_type = jnp.where(due, new_type, state.warp_type)
    ratio = jnp.where(due, ratio_now, state.ratio)
    hits = jnp.where(due, 0, hits)
    accesses = jnp.where(due, 0, accesses)
    return ClassifierState(hits, accesses, warp_type, ratio)


def force_classify(state: ClassifierState, *, mostly_hit_threshold=0.8,
                   mostly_miss_threshold=0.2, min_samples: int = 1
                   ) -> ClassifierState:
    """Classify immediately from whatever counts exist (end-of-window)."""
    ratio_now = state.hits.astype(jnp.float32) / jnp.maximum(state.accesses, 1)
    new_type = WT.classify(ratio_now, state.accesses,
                           mostly_hit_threshold=mostly_hit_threshold,
                           mostly_miss_threshold=mostly_miss_threshold,
                           min_samples=min_samples)
    keep = state.accesses < min_samples
    return ClassifierState(
        state.hits, state.accesses,
        jnp.where(keep, state.warp_type, new_type),
        jnp.where(keep, state.ratio, ratio_now))
