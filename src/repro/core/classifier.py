"""Online warp-type identification (paper §3.1, mechanism ①).

Hardware model: two counters per warp (hits, accesses) incremented at the
shared cache, sampled every ``sampling_interval`` accesses; at each sampling
boundary the warp's type is re-evaluated from the observed hit ratio and the
counters reset. Between boundaries the warp keeps its last classification
(paper observation O2: divergence behaviour is stable over long periods).

The sampling/reclassification window is a first-class knob (ISSUE 5):
``sampling_interval`` may be a *traced* value (the policy layer supplies a
per-policy window via ``PolicyArrays.reclass_interval``), and
``max_windows`` caps how many sampling windows are allowed to update the
label — ``max_windows=1`` is the "stale phase-0 labeling" baseline that
classifies each warp once and then freezes, the foil the phased scenario
family measures online reclassification against. The window bookkeeping
follows the EAF generation-bump idiom: ``windows`` counts completed
windows per warp and label updates are gated on it, instead of keeping a
separate frozen-label array.

Bypassed requests are counted as *misses* (they would have been: the warp
was classified mostly/all-miss). To let a reformed warp escape the bypass
class, a small fraction of bypassed requests is still probed through the
cache lookup path (``probe_interval``), mirroring the paper's periodic
resampling discussion.

Everything is functional and vectorized over warps so both the altitude-A
simulator and the altitude-B serving pool manager use the same code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import warp_types as WT


class ClassifierState(NamedTuple):
    hits: jnp.ndarray        # i32[W] hits in current sampling window
    accesses: jnp.ndarray    # i32[W] accesses in current sampling window
    warp_type: jnp.ndarray   # i32[W] current classification
    ratio: jnp.ndarray       # f32[W] last sampled hit ratio
    windows: jnp.ndarray     # i32[W] completed sampling windows


def init(n_warps: int) -> ClassifierState:
    return ClassifierState(
        hits=jnp.zeros((n_warps,), jnp.int32),
        accesses=jnp.zeros((n_warps,), jnp.int32),
        warp_type=jnp.full((n_warps,), WT.BALANCED, jnp.int32),
        ratio=jnp.full((n_warps,), 0.5, jnp.float32),
        windows=jnp.zeros((n_warps,), jnp.int32),
    )


def observe(state: ClassifierState, warp_id, is_hit, *,
            sampling_interval=256,
            mostly_hit_threshold: float = 0.8,
            mostly_miss_threshold: float = 0.2,
            weight=None, max_windows=None) -> ClassifierState:
    """Record one (or a batch of) access outcome(s) and re-classify any warp
    whose sampling window filled up.

    warp_id: i32[] or i32[N]; is_hit: bool same shape.
    sampling_interval may be a traced scalar (policy-visible window).
    max_windows (optional, traced ok): label updates stop after this many
    completed windows — the window still resets (counters keep cycling,
    ``ratio`` telemetry stays live), only ``warp_type`` freezes.
    """
    warp_id = jnp.atleast_1d(warp_id)
    is_hit = jnp.atleast_1d(is_hit).astype(jnp.int32)
    if weight is None:
        weight = jnp.ones_like(is_hit)
    hits = state.hits.at[warp_id].add(is_hit * weight)
    accesses = state.accesses.at[warp_id].add(weight)

    due = accesses >= sampling_interval
    ratio_now = hits.astype(jnp.float32) / jnp.maximum(accesses, 1)
    new_type = WT.classify(ratio_now, accesses,
                           mostly_hit_threshold=mostly_hit_threshold,
                           mostly_miss_threshold=mostly_miss_threshold)
    relabel = due if max_windows is None \
        else due & (state.windows < max_windows)
    warp_type = jnp.where(relabel, new_type, state.warp_type)
    ratio = jnp.where(due, ratio_now, state.ratio)
    windows = state.windows + due.astype(jnp.int32)
    hits = jnp.where(due, 0, hits)
    accesses = jnp.where(due, 0, accesses)
    return ClassifierState(hits, accesses, warp_type, ratio, windows)


def force_classify(state: ClassifierState, *, mostly_hit_threshold=0.8,
                   mostly_miss_threshold=0.2, min_samples: int = 1
                   ) -> ClassifierState:
    """Classify immediately from whatever counts exist (end-of-window)."""
    ratio_now = state.hits.astype(jnp.float32) / jnp.maximum(state.accesses, 1)
    new_type = WT.classify(ratio_now, state.accesses,
                           mostly_hit_threshold=mostly_hit_threshold,
                           mostly_miss_threshold=mostly_miss_threshold,
                           min_samples=min_samples)
    keep = state.accesses < min_samples
    return ClassifierState(
        state.hits, state.accesses,
        jnp.where(keep, state.warp_type, new_type),
        jnp.where(keep, state.ratio, ratio_now),
        state.windows)
