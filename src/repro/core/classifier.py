"""Online warp-type identification (paper §3.1, mechanism ①).

Hardware model: two counters per warp (hits, accesses) incremented at the
shared cache, sampled every ``sampling_interval`` accesses; at each sampling
boundary the warp's type is re-evaluated from the observed hit ratio and the
counters reset. Between boundaries the warp keeps its last classification
(paper observation O2: divergence behaviour is stable over long periods).

The sampling/reclassification window is a first-class knob (ISSUE 5):
``sampling_interval`` may be a *traced* value (the policy layer supplies a
per-policy window via ``PolicyArrays.reclass_interval``), and
``max_windows`` caps how many sampling windows are allowed to update the
label — ``max_windows=1`` is the "stale phase-0 labeling" baseline that
classifies each warp once and then freezes, the foil the phased scenario
family measures online reclassification against. The window bookkeeping
follows the EAF generation-bump idiom: ``windows`` counts completed
windows per warp and label updates are gated on it, instead of keeping a
separate frozen-label array.

Under a bypass policy most of a bypassing warp's requests never touch the
cache, so they carry no hit/miss evidence. The classifier therefore keeps
TWO per-window counters: ``accesses`` counts every valid request (the
window/probe *cadence* clock — it must keep ticking while a warp
bypasses, or the probe phase would never come around again), while
``sampled`` counts only the requests that actually took the cache path
(non-bypassed requests plus the periodic probes — every
``probe_interval``-th access of a bypassing warp is forced down the
cache path by the engines). The classified hit ratio is
``hits / sampled``: the undiluted cache-path sample. Before PR 7 the
ratio was ``hits / accesses``, which capped a bypassing warp's
observable ratio at ``1/probe_interval`` = 0.125 < the 0.2 mostly-miss
threshold — labels ratcheted down and could never recover (the bug
DESIGN.md §11 kept on record since PR 5). With the probe-sample window a
reformed warp's probe stream can exceed the 0.8 mostly-hit threshold
and the label ratchets back up.

``min_samples`` adapts to the probe cadence: a window of
``sampling_interval`` accesses guarantees only ``interval /
probe_interval`` cache-path samples for a fully-bypassing warp, so the
classify floor is ``clip(interval / probe_interval, 1, 8)`` — small
windows (e.g. the win-32 fast ladder rung) would otherwise never reach
8 probes and oscillate through BALANCED every window. A window that
closes with NO cache-path sample at all (e.g. a token-less PCAL warp)
relabels to BALANCED: no evidence reverts to the prior.

Everything is functional and vectorized over warps so both the altitude-A
simulator and the altitude-B serving pool manager use the same code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import warp_types as WT


class ClassifierState(NamedTuple):
    hits: jnp.ndarray        # i32[W] cache-path hits in current window
    accesses: jnp.ndarray    # i32[W] ALL valid requests in current window
    #                          (window + probe cadence clock)
    warp_type: jnp.ndarray   # i32[W] current classification
    ratio: jnp.ndarray       # f32[W] last sampled cache-path hit ratio
    windows: jnp.ndarray     # i32[W] completed sampling windows
    sampled: jnp.ndarray     # i32[W] cache-path requests in current window
    #                          (non-bypassed + probes; the classify sample)


def init(n_warps: int) -> ClassifierState:
    return ClassifierState(
        hits=jnp.zeros((n_warps,), jnp.int32),
        accesses=jnp.zeros((n_warps,), jnp.int32),
        warp_type=jnp.full((n_warps,), WT.BALANCED, jnp.int32),
        ratio=jnp.full((n_warps,), 0.5, jnp.float32),
        windows=jnp.zeros((n_warps,), jnp.int32),
        sampled=jnp.zeros((n_warps,), jnp.int32),
    )


def min_probe_samples(sampling_interval, probe_interval):
    """Classify floor adapted to the probe cadence: a window of
    ``sampling_interval`` accesses guarantees only ``interval /
    probe_interval`` cache-path samples for a fully-bypassing warp.
    Shared by ``observe`` and the wavefront engine's fused observe
    variants so the three observe paths cannot desynchronize."""
    guaranteed = jnp.asarray(sampling_interval, jnp.float32) // jnp.maximum(
        jnp.asarray(probe_interval, jnp.float32), 1.0)
    return jnp.clip(guaranteed, 1.0, 8.0)


def observe(state: ClassifierState, warp_id, is_hit, *,
            sampling_interval=256,
            mostly_hit_threshold: float = 0.8,
            mostly_miss_threshold: float = 0.2,
            weight=None, max_windows=None, probed=None,
            probe_interval=None) -> ClassifierState:
    """Record one (or a batch of) access outcome(s) and re-classify any warp
    whose sampling window filled up.

    warp_id: i32[] or i32[N]; is_hit: bool same shape.
    sampling_interval may be a traced scalar (policy-visible window).
    max_windows (optional, traced ok): label updates stop after this many
    completed windows — the window still resets (counters keep cycling,
    ``ratio`` telemetry stays live), only ``warp_type`` freezes.
    probed (optional): i32 mask/weight of requests that took the cache
    path (non-bypassed + periodic probes). Defaults to ``weight`` (every
    counted request is a cache-path sample — the non-bypass case).
    Requests with ``probed == 0`` still advance the ``accesses`` cadence
    clock but carry no hit/miss evidence: the classified ratio is
    ``hits / sampled`` over cache-path samples only, so a bypassing
    warp's ratio is NOT diluted toward ``1/probe_interval``.
    probe_interval (optional, traced ok): the probe cadence, used only
    to adapt the classify floor (``min_probe_samples``); None keeps the
    default floor of 8 samples.
    """
    warp_id = jnp.atleast_1d(warp_id)
    is_hit = jnp.atleast_1d(is_hit).astype(jnp.int32)
    if weight is None:
        weight = jnp.ones_like(is_hit)
    if probed is None:
        probed = weight
    hits = state.hits.at[warp_id].add(is_hit * probed)
    accesses = state.accesses.at[warp_id].add(weight)
    sampled = state.sampled.at[warp_id].add(probed)

    due = accesses >= sampling_interval
    ratio_now = hits.astype(jnp.float32) / jnp.maximum(sampled, 1)
    min_samples = 8 if probe_interval is None \
        else min_probe_samples(sampling_interval, probe_interval)
    new_type = WT.classify(ratio_now, sampled,
                           mostly_hit_threshold=mostly_hit_threshold,
                           mostly_miss_threshold=mostly_miss_threshold,
                           min_samples=min_samples)
    relabel = due if max_windows is None \
        else due & (state.windows < max_windows)
    warp_type = jnp.where(relabel, new_type, state.warp_type)
    ratio = jnp.where(due, ratio_now, state.ratio)
    windows = state.windows + due.astype(jnp.int32)
    hits = jnp.where(due, 0, hits)
    accesses = jnp.where(due, 0, accesses)
    sampled = jnp.where(due, 0, sampled)
    return ClassifierState(hits=hits, accesses=accesses,
                           warp_type=warp_type, ratio=ratio,
                           windows=windows, sampled=sampled)


def force_classify(state: ClassifierState, *, mostly_hit_threshold=0.8,
                   mostly_miss_threshold=0.2, min_samples: int = 1
                   ) -> ClassifierState:
    """Classify immediately from whatever counts exist (end-of-window)."""
    ratio_now = state.hits.astype(jnp.float32) / jnp.maximum(state.sampled, 1)
    new_type = WT.classify(ratio_now, state.sampled,
                           mostly_hit_threshold=mostly_hit_threshold,
                           mostly_miss_threshold=mostly_miss_threshold,
                           min_samples=min_samples)
    keep = state.sampled < min_samples
    return ClassifierState(
        hits=state.hits, accesses=state.accesses,
        warp_type=jnp.where(keep, state.warp_type, new_type),
        ratio=jnp.where(keep, state.ratio, ratio_now),
        windows=state.windows, sampled=state.sampled)
