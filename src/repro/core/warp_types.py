"""Warp-type taxonomy (paper Fig 3).

Five types keyed by shared-cache hit ratio, sampled over an interval:

    all-miss     ratio == 0
    mostly-miss  0 < ratio <= mostly_miss_threshold   (paper: ~20%)
    balanced     mmiss < ratio < mostly_hit_threshold
    mostly-hit   mhit <= ratio < 1
    all-hit      ratio == 1

Codes are ordered so that *larger code == higher cache utility*, which lets
the policies compare with a single threshold (e.g. bypass iff
type <= MOSTLY_MISS, prioritize iff type >= MOSTLY_HIT).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

ALL_MISS = 0
MOSTLY_MISS = 1
BALANCED = 2
MOSTLY_HIT = 3
ALL_HIT = 4

NUM_TYPES = 5
TYPE_NAMES = ("all-miss", "mostly-miss", "balanced", "mostly-hit", "all-hit")

# epsilon so that e.g. 127/128 still counts as mostly-hit, not all-hit
_EPS = 1e-6


def classify(hit_ratio, accesses, *, mostly_hit_threshold: float = 0.8,
             mostly_miss_threshold: float = 0.2, min_samples: int = 8):
    """Vectorized hit-ratio -> warp-type. Unsampled warps default BALANCED.

    hit_ratio: f32[...] in [0,1]; accesses: i32[...] sample counts.
    """
    r = hit_ratio
    t = jnp.full(jnp.shape(r), BALANCED, jnp.int32)
    t = jnp.where(r <= mostly_miss_threshold, MOSTLY_MISS, t)
    t = jnp.where(r <= _EPS, ALL_MISS, t)
    t = jnp.where(r >= mostly_hit_threshold, MOSTLY_HIT, t)
    t = jnp.where(r >= 1.0 - _EPS, ALL_HIT, t)
    return jnp.where(accesses >= min_samples, t,
                     jnp.full_like(t, BALANCED))


def _ladder_np(hit_ratio, mostly_hit_threshold: float,
               mostly_miss_threshold: float) -> np.ndarray:
    """The ratio->type threshold ladder, numpy-vectorized in float32 —
    the single numpy-side source of the comparisons ``classify`` makes
    (weakly typed python-float thresholds compare at the array dtype, so
    the jnp and numpy forms agree bit-for-bit). ``classify_np`` and
    ``oracle_type_np`` both call this, so the ladder cannot
    desynchronize between them."""
    r = np.asarray(hit_ratio, np.float32)
    t = np.full(r.shape, BALANCED, np.int32)
    t = np.where(r <= np.float32(mostly_miss_threshold), MOSTLY_MISS, t)
    t = np.where(r <= np.float32(_EPS), ALL_MISS, t)
    t = np.where(r >= np.float32(mostly_hit_threshold), MOSTLY_HIT, t)
    t = np.where(r >= np.float32(1.0 - _EPS), ALL_HIT, t)
    return t


def classify_np(hit_ratio: float, accesses: int, *,
                mostly_hit_threshold: float = 0.8,
                mostly_miss_threshold: float = 0.2,
                min_samples: int = 8) -> int:
    """Scalar numpy mirror of `classify` for host-side control planes."""
    if accesses < min_samples:
        return BALANCED
    return int(_ladder_np(hit_ratio, mostly_hit_threshold,
                          mostly_miss_threshold))


def oracle_type_np(reuse_p, ws_lines, *, mostly_hit_threshold: float = 0.8,
                   mostly_miss_threshold: float = 0.2) -> np.ndarray:
    """Vectorized numpy ground-truth labeling from lowered trace params.

    The warp type a converged classifier would settle on, given the
    phase's reuse probability (≈ the warp's steady-state hit ratio) and
    working-set size (0 lines = pure streaming = all-miss regardless of
    the nominal reuse column). Same float32 threshold semantics as
    ``classify``/``classify_np`` (shared ``_ladder_np``); used by
    tracegen to emit the per-phase oracle labels the engines' oracle
    labeling mode consumes.
    """
    t = _ladder_np(reuse_p, mostly_hit_threshold, mostly_miss_threshold)
    return np.where(np.asarray(ws_lines) == 0,
                    np.int32(ALL_MISS), t).astype(np.int32)


def is_bypass_type(warp_type):
    """Mostly-miss and all-miss warps bypass the shared cache (paper §3.2)."""
    return warp_type <= MOSTLY_MISS


def is_priority_type(warp_type):
    """Mostly-hit (and mischaracterized all-hit) requests take the
    high-priority memory queue (paper §3.4)."""
    return warp_type >= MOSTLY_HIT


def insertion_rank(warp_type, max_rank: int = 3):
    """Warp-type -> RRIP-style insertion rank (paper §3.3).

    0 = insert at MRU (evict last) ... max_rank = insert at LRU (evict
    first). all/mostly-hit -> 0, balanced -> max_rank-1, mostly/all-miss ->
    max_rank.
    """
    r = jnp.full(jnp.shape(warp_type), max_rank, jnp.int32)
    r = jnp.where(warp_type == BALANCED, max_rank - 1, r)
    r = jnp.where(warp_type >= MOSTLY_HIT, 0, r)
    return r
