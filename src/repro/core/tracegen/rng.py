"""Counter-based RNG primitives for trace generation.

Every draw is a pure function of ``(stream_key, index)`` — there is no
sequential generator state, so the same cell yields the same bits whether
it is computed alone in a Python loop (``ref.py``) or for the whole
I×W×L×seeds block at once (``sampler.py``). This is what makes the
vectorized/loop differential test bit-exact instead of statistical.

The construction is splitmix64: a draw at index ``i`` of the stream with
key ``k`` finalizes the state ``k + i * GAMMA`` with the murmur-style
avalanche. Two implementations are provided and tested against each
other (tests/test_tracegen.py):

  * array ops on ``np.uint64`` (wrapping arithmetic) for the sampler;
  * plain Python ints masked to 64 bits for the scalar reference, which
    is ~5x faster than NumPy scalar math in a tight loop.
"""
from __future__ import annotations

import numpy as np

GAMMA = 0x9E3779B97F4A7C15
_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB
_MASK = (1 << 64) - 1

_U = np.uint64
_G = _U(GAMMA)
_M1u = _U(_M1)
_M2u = _U(_M2)

# named sub-stream tags: stream key = mix64(root + TAG * GAMMA)
TAG_ARCH = 1        # per-warp archetype draw
TAG_PHASE = 2       # per-warp phase-flip uniform
TAG_PHASE_PICK = 3  # per-warp flipped-archetype pick
TAG_WS = 4          # per-warp working-set permutation key
TAG_PC = 5          # per-warp PC table
TAG_POOL = 6        # shared-pool line addresses
TAG_REUSE_U = 7     # per-cell reuse uniform
TAG_SHARED_U = 8    # per-cell shared-pool uniform
TAG_SHARED_IDX = 9  # per-cell shared-pool index
TAG_WS_IDX = 10     # per-cell working-set index
# phased-schedule tags (ISSUE 5): indexed at p*W + w so every phase of
# every warp has its own coordinate; the legacy two-half path keeps its
# original TAG_PHASE/TAG_PHASE_PICK draws at index w, byte-identical
TAG_PHASE_MIX = 11  # per-(phase, warp) redrawn-archetype uniform
TAG_WS_CHURN = 12   # per-(phase, warp) working-set churn uniform
TAG_WS_KEY = 13     # per-(phase, warp) re-keyed working-set permutation

_INV53 = float(2.0 ** -53)


def mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer on uint64 arrays (wrapping arithmetic).
    np.errstate silences the overflow RuntimeWarning numpy emits for 0-d
    inputs — wraparound is the intended behaviour here."""
    with np.errstate(over="ignore"):
        z = np.asarray(x, _U)
        z = (z ^ (z >> _U(30))) * _M1u
        z = (z ^ (z >> _U(27))) * _M2u
        return z ^ (z >> _U(31))


def stream_key(root: np.ndarray, tag: int) -> np.ndarray:
    """Key for the named sub-stream ``tag`` of the trace rooted at ``root``."""
    with np.errstate(over="ignore"):
        return mix64(np.asarray(root, _U) + _U((tag * GAMMA) & _MASK))


def bits(key: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """64 random bits at ``idx`` of the stream ``key`` (broadcasting)."""
    with np.errstate(over="ignore"):
        return mix64(np.asarray(key, _U) + np.asarray(idx, _U) * _G)


def uniform(key: np.ndarray, idx: np.ndarray) -> np.ndarray:
    """f64 uniforms in [0, 1) — top 53 bits of the draw."""
    return (bits(key, idx) >> _U(11)).astype(np.float64) * _INV53


def randint(key: np.ndarray, idx: np.ndarray, n) -> np.ndarray:
    """Integers in [0, n). Modulo bias is < n / 2**64 — negligible for the
    n <= 2**20 used here. ``n`` may be an array (per-warp working sets)."""
    return (bits(key, idx) % np.asarray(n, _U)).astype(np.int64)


# ---------------------------------------------------------------------------
# scalar (Python-int) mirror — used by the loop reference generator
# ---------------------------------------------------------------------------

def mix64_scalar(x: int) -> int:
    z = x & _MASK
    z = ((z ^ (z >> 30)) * _M1) & _MASK
    z = ((z ^ (z >> 27)) * _M2) & _MASK
    return z ^ (z >> 31)


def stream_key_scalar(root: int, tag: int) -> int:
    return mix64_scalar((root + tag * GAMMA) & _MASK)


def bits_scalar(key: int, idx: int) -> int:
    return mix64_scalar((key + idx * GAMMA) & _MASK)


def uniform_scalar(key: int, idx: int) -> float:
    return (bits_scalar(key, idx) >> 11) * _INV53


def randint_scalar(key: int, idx: int, n: int) -> int:
    return bits_scalar(key, idx) % n


# ---------------------------------------------------------------------------
# keyed 12-bit permutation (working-set layout)
# ---------------------------------------------------------------------------

def perm12(j: np.ndarray, key: np.ndarray) -> np.ndarray:
    """Bijection on [0, 4096) keyed by ``key`` — a 3-round 6|6 Feistel
    whose round function is one mix64. Used to pick each warp's private
    working set without replacement (distinct lines by construction)."""
    with np.errstate(over="ignore"):
        j = np.asarray(j, _U)
        lo6 = _U(63)
        left, right = j >> _U(6), j & lo6
        for rnd in range(3):
            f = mix64(np.asarray(key, _U)
                      + (right | _U(rnd << 6)) * _G) & lo6
            left, right = right, left ^ f
        return ((left << _U(6)) | right).astype(np.int64)


def perm12_scalar(j: int, key: int) -> int:
    left, right = j >> 6, j & 63
    for rnd in range(3):
        f = mix64_scalar((key + ((right | (rnd << 6)) * GAMMA)) & _MASK) & 63
        left, right = right, left ^ f
    return (left << 6) | right
