"""Pure-numpy batched trace sampler.

Materializes ``lines``/``pcs`` for every (instruction, warp, lane) cell —
and every seed — in one set of array ops. Each cell's branch structure
mirrors the original loop generator:

    u < reuse?   ──no──►  streaming address (positional fresh slot)
        │yes
    u2 < shared? ──no──►  private working-set line
        │yes
        └──────────────►  shared-pool line

but every uniform/index is a counter-RNG draw addressed by the cell's
flat index, so the result is independent of evaluation order and
bit-identical to ``ref.generate_ref`` (tests/test_tracegen.py). Phase
schedules only change WHICH per-phase parameters (archetype scalars,
working-set table) a cell gathers — the cell draws themselves are
phase-agnostic, which is why a single-phase schedule reduces
byte-identically to the legacy static spec (tests/test_metamorphic.py).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core import warp_types as WT
from repro.core.tracegen import rng
from repro.core.tracegen.spec import (TraceSpec, lower, lowered_gap,
                                      phase_of_instr, trace_key)


def _sample_cells(spec: TraceSpec, seeds) -> Dict[str, np.ndarray]:
    """All cells for all seeds: lines i32[S, I, W, L], pcs i32[S, I, W]."""
    seeds = np.atleast_1d(np.asarray(seeds, np.int64))
    n_seeds = len(seeds)
    i_n, w_n, l_n = spec.n_instr, spec.n_warps, spec.lines_per_instr
    layout, wp = lower(spec, seeds)
    phase_of = phase_of_instr(spec)                               # i64[I]

    roots = np.asarray([trace_key(spec.name, int(s)) for s in seeds],
                       np.uint64).reshape(-1, 1, 1, 1)            # [S,1,1,1]
    ii = np.arange(i_n, dtype=np.int64)[:, None, None]            # [I,1,1]
    wi = np.arange(w_n, dtype=np.int64)[None, :, None]            # [1,W,1]
    li = np.arange(l_n, dtype=np.int64)[None, None, :]            # [1,1,L]
    flat = ((ii * w_n + wi) * l_n + li).astype(np.uint64)[None]   # [1,I,W,L]

    # per-phase archetype scalars, gathered to [S, I, W, 1]
    sg = np.arange(n_seeds)[:, None, None, None]                  # [S,1,1,1]
    pg = phase_of[None, :, None, None]                            # [1,I,1,1]
    wg = np.arange(w_n)[None, None, :, None]                      # [1,1,W,1]
    ws_size_t = wp.ws_size[sg, wg, pg]                            # [S,I,W,1]
    reuse_t = wp.reuse[sg, wg, pg]
    shared_t = wp.shared[sg, wg, pg]

    u = rng.uniform(rng.stream_key(roots, rng.TAG_REUSE_U), flat)
    reuse_hit = (ws_size_t > 0) & (u < reuse_t)
    u2 = rng.uniform(rng.stream_key(roots, rng.TAG_SHARED_U), flat)
    use_shared = reuse_hit & (shared_t > 0) & (u2 < shared_t)

    pool_idx = rng.randint(rng.stream_key(roots, rng.TAG_SHARED_IDX),
                           flat, spec.shared_pool_lines)
    shared_line = wp.pool[sg, pool_idx]                           # [S,I,W,L]

    ws_idx = rng.randint(rng.stream_key(roots, rng.TAG_WS_IDX), flat,
                         np.maximum(ws_size_t, 1))
    ws_line = wp.ws_table[sg, wg, pg, ws_idx]                     # [S,I,W,L]

    fresh_line = layout.fresh_addr(wi[None], ii[None] * l_n + li[None])

    lines = np.where(use_shared, shared_line,
                     np.where(reuse_hit, ws_line, fresh_line))

    pcs = wp.pc_table[np.arange(n_seeds)[:, None, None],
                      np.arange(w_n)[None, None, :],
                      (np.arange(i_n) % spec.n_pcs)[None, :, None]]
    # per-phase ground-truth labels, expanded to [S, I, W] for the
    # engines' oracle labeling mode
    wt_phase = WT.oracle_type_np(wp.reuse, wp.ws_size)            # [S,W,P]
    oracle = wt_phase[np.arange(n_seeds)[:, None, None],
                      np.arange(w_n)[None, None, :],
                      phase_of[None, :, None]]                    # [S,I,W]
    return {
        "lines": lines.astype(np.int32),
        "pcs": pcs.astype(np.int32),
        "archetype": wp.arch[:, :, 0].astype(np.int32),           # [S, W]
        "archetype2": wp.arch[:, :, -1].astype(np.int32),
        "oracle_wtype": oracle.astype(np.int32),
        "archetype_phases": wp.arch.astype(np.int32),             # [S,W,P]
    }


def generate(spec: TraceSpec, seed: int = 0) -> Dict[str, np.ndarray]:
    """One (spec, seed) trace with the original ``workloads.generate``
    output contract: lines i32[I, W, L], pcs i32[I, W], compute_gap f32
    (a scalar — or f32[I] when the phase schedule varies intensity),
    archetype i32[W] (+ archetype2 for the stability tests), plus
    oracle_wtype i32[I, W] (ground-truth per-phase labels) and
    archetype_phases i32[W, P] (the full per-phase archetype matrix)."""
    out = _sample_cells(spec, [seed])
    return {
        "lines": out["lines"][0],
        "pcs": out["pcs"][0],
        "compute_gap": lowered_gap(spec),
        "archetype": out["archetype"][0],
        "archetype2": out["archetype2"][0],
        "oracle_wtype": out["oracle_wtype"][0],
        "archetype_phases": out["archetype_phases"][0],
    }


def generate_batch(specs: Sequence[TraceSpec],
                   seeds: Sequence[int]) -> Dict[str, np.ndarray]:
    """Stacked traces for ``specs`` × ``seeds``, shaped to feed
    ``simulate_sweep`` directly:

        lines i32[N, S, I, W, L], pcs i32[N, S, I, W],
        compute_gap f32[N, S] (or f32[N, S, I] if any spec's schedule
        varies intensity), archetype i32[N, S, W],
        oracle_wtype i32[N, S, I, W]

    Reshaping the leading two axes to one [N*S] axis gives the
    seed-stacked trace format ``simulate_sweep`` vmaps over, so one
    jitted call sweeps policies × seeds × workloads. All specs must share
    (n_instr, n_warps, lines_per_instr) — the trace shape.
    (``archetype_phases`` is a per-spec key only: schedules of different
    phase counts don't stack.)
    """
    shapes = {(s.n_instr, s.n_warps, s.lines_per_instr) for s in specs}
    if len(shapes) != 1:
        raise ValueError(f"heterogeneous trace shapes in batch: {shapes}")
    (n_instr, _, _), = shapes
    outs = [_sample_cells(s, seeds) for s in specs]
    for o in outs:                      # phase counts differ across specs
        o.pop("archetype_phases")
    gaps = [lowered_gap(s) for s in specs]
    if any(np.ndim(g) > 0 for g in gaps):
        gaps = [np.broadcast_to(np.asarray(g, np.float32), (n_instr,))
                for g in gaps]
        gap = np.broadcast_to(
            np.stack(gaps)[:, None, :],
            (len(specs), len(seeds), n_instr)).copy()
    else:
        gap = np.broadcast_to(
            np.asarray(gaps, np.float32)[:, None],
            (len(specs), len(seeds))).copy()
    return {
        "lines": np.stack([o["lines"] for o in outs]),
        "pcs": np.stack([o["pcs"] for o in outs]),
        "compute_gap": gap,
        "archetype": np.stack([o["archetype"] for o in outs]),
        "archetype2": np.stack([o["archetype2"] for o in outs]),
        "oracle_wtype": np.stack([o["oracle_wtype"] for o in outs]),
    }
