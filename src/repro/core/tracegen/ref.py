"""Loop reference generator — the legacy triple-nested shape, kept as the
exact-parity oracle for the vectorized sampler.

Walks warps → instructions → lanes exactly like the original
``workloads.generate`` did, but draws every random value from the
counter RNG at the cell's own (tag, index) coordinate, so it must agree
with ``sampler.generate`` bit-for-bit (tests/test_tracegen.py runs the
differential over every workload at 3 seeds; the hypothesis fuzz in
tests/test_tracegen_properties.py extends it over random phase
schedules). Scalar Python-int RNG mirrors (``rng.*_scalar``) keep the
loop tolerably fast; their equality with the array versions is itself
under test.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core import warp_types as WT
from repro.core.tracegen import rng
from repro.core.tracegen.spec import (TraceSpec, compile_schedule,
                                      lowered_gap, make_layout,
                                      phase_of_instr, trace_key)


def generate_ref(spec: TraceSpec, seed: int = 0) -> Dict[str, np.ndarray]:
    """Same output contract as ``sampler.generate``."""
    layout = make_layout(spec)
    tab = spec.archetype_table()
    n_arch = tab.shape[0]
    max_ws = max(int(tab[:, 0].max()), 1)
    i_n, w_n, l_n = spec.n_instr, spec.n_warps, spec.lines_per_instr
    _, plans = compile_schedule(spec)
    phase_of = phase_of_instr(spec)
    n_ph = len(plans)

    root = trace_key(spec.name, seed)
    k_arch = rng.stream_key_scalar(root, rng.TAG_ARCH)
    k_phase = rng.stream_key_scalar(root, rng.TAG_PHASE)
    k_pick = rng.stream_key_scalar(root, rng.TAG_PHASE_PICK)
    k_pmix = rng.stream_key_scalar(root, rng.TAG_PHASE_MIX)
    k_ws = rng.stream_key_scalar(root, rng.TAG_WS)
    k_churn = rng.stream_key_scalar(root, rng.TAG_WS_CHURN)
    k_wskey = rng.stream_key_scalar(root, rng.TAG_WS_KEY)
    k_pc = rng.stream_key_scalar(root, rng.TAG_PC)
    k_pool = rng.stream_key_scalar(root, rng.TAG_POOL)
    k_reuse = rng.stream_key_scalar(root, rng.TAG_REUSE_U)
    k_shared_u = rng.stream_key_scalar(root, rng.TAG_SHARED_U)
    k_shared_idx = rng.stream_key_scalar(root, rng.TAG_SHARED_IDX)
    k_ws_idx = rng.stream_key_scalar(root, rng.TAG_WS_IDX)

    pool = [rng.randint_scalar(k_pool, p, layout.pool_region)
            for p in range(spec.shared_pool_lines)]

    lines = np.full((i_n, w_n, l_n), -1, np.int32)
    pcs = np.zeros((i_n, w_n), np.int32)
    arch_phases = np.zeros((w_n, n_ph), np.int32)
    oracle = np.zeros((i_n, w_n), np.int32)

    def inv_cdf(cum, u):
        return min(int(np.searchsorted(cum, u, side="right")), n_arch - 1)

    for wi in range(w_n):
        # per-phase archetype / working-set-key chains, scalar mirror of
        # spec.lower (counter RNG: draw order is irrelevant, only the
        # (tag, index) coordinates must match)
        archs = [inv_cdf(plans[0].cum, rng.uniform_scalar(k_arch, wi))]
        wkeys = [rng.bits_scalar(k_ws, wi)]
        for p, plan in enumerate(plans[1:], start=1):
            if plan.legacy:
                flip = rng.uniform_scalar(k_phase, wi) < plan.flip_prob
                a = rng.randint_scalar(k_pick, wi, n_arch) if flip \
                    else archs[-1]
                archs.append(a)
                wkeys.append(wkeys[-1])
                continue
            pidx = p * w_n + wi
            flip = rng.uniform_scalar(k_phase, pidx) < plan.flip_prob
            a = inv_cdf(plan.cum, rng.uniform_scalar(k_pmix, pidx)) \
                if flip else archs[-1]
            archs.append(a)
            rekey = rng.uniform_scalar(k_churn, pidx) < plan.churn
            wkeys.append(rng.bits_scalar(k_wskey, pidx) if rekey
                         else wkeys[-1])
        arch_phases[wi] = archs

        ws_base = int(layout.ws_base(wi))
        ws_by_key = {}
        for key in wkeys:
            if key not in ws_by_key:
                ws_by_key[key] = [ws_base + rng.perm12_scalar(j, key)
                                  for j in range(max_ws)]
        pcs_w = [rng.randint_scalar(k_pc, wi * spec.n_pcs + j, 1 << 16)
                 for j in range(spec.n_pcs)]
        params = [(int(tab[a, 0]), float(tab[a, 1]), float(tab[a, 2]))
                  for a in archs]
        oracle_w = [int(WT.oracle_type_np(tab[a, 1], tab[a, 0]))
                    for a in archs]

        for ii in range(i_n):
            p = int(phase_of[ii])
            ws_size, reuse, shared = params[p]
            ws = ws_by_key[wkeys[p]]
            pcs[ii, wi] = pcs_w[ii % spec.n_pcs]
            oracle[ii, wi] = oracle_w[p]
            for li in range(l_n):
                flat = (ii * w_n + wi) * l_n + li
                u = rng.uniform_scalar(k_reuse, flat)
                u2 = rng.uniform_scalar(k_shared_u, flat)
                if ws_size and u < reuse:
                    if shared and u2 < shared:
                        lines[ii, wi, li] = pool[rng.randint_scalar(
                            k_shared_idx, flat, spec.shared_pool_lines)]
                    else:
                        lines[ii, wi, li] = ws[rng.randint_scalar(
                            k_ws_idx, flat, max(ws_size, 1))]
                else:
                    lines[ii, wi, li] = layout.fresh_addr(wi, ii * l_n + li)

    return {
        "lines": lines,
        "pcs": pcs,
        "compute_gap": lowered_gap(spec),
        "archetype": arch_phases[:, 0].copy(),
        "archetype2": arch_phases[:, -1].copy(),
        "oracle_wtype": oracle,
        "archetype_phases": arch_phases,
    }
