"""Loop reference generator — the legacy triple-nested shape, kept as the
exact-parity oracle for the vectorized sampler.

Walks warps → instructions → lanes exactly like the original
``workloads.generate`` did, but draws every random value from the
counter RNG at the cell's own (tag, index) coordinate, so it must agree
with ``sampler.generate`` bit-for-bit (tests/test_tracegen.py runs the
differential over every workload at 3 seeds). Scalar Python-int RNG
mirrors (``rng.*_scalar``) keep the loop tolerably fast; their equality
with the array versions is itself under test.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.tracegen import rng
from repro.core.tracegen.spec import TraceSpec, make_layout, trace_key


def generate_ref(spec: TraceSpec, seed: int = 0) -> Dict[str, np.ndarray]:
    """Same output contract as ``sampler.generate``."""
    layout = make_layout(spec)
    tab = spec.archetype_table()
    n_arch = tab.shape[0]
    max_ws = max(int(tab[:, 0].max()), 1)
    cum = np.cumsum(np.asarray(spec.mix, np.float64))
    i_n, w_n, l_n = spec.n_instr, spec.n_warps, spec.lines_per_instr

    root = trace_key(spec.name, seed)
    k_arch = rng.stream_key_scalar(root, rng.TAG_ARCH)
    k_phase = rng.stream_key_scalar(root, rng.TAG_PHASE)
    k_pick = rng.stream_key_scalar(root, rng.TAG_PHASE_PICK)
    k_ws = rng.stream_key_scalar(root, rng.TAG_WS)
    k_pc = rng.stream_key_scalar(root, rng.TAG_PC)
    k_pool = rng.stream_key_scalar(root, rng.TAG_POOL)
    k_reuse = rng.stream_key_scalar(root, rng.TAG_REUSE_U)
    k_shared_u = rng.stream_key_scalar(root, rng.TAG_SHARED_U)
    k_shared_idx = rng.stream_key_scalar(root, rng.TAG_SHARED_IDX)
    k_ws_idx = rng.stream_key_scalar(root, rng.TAG_WS_IDX)

    pool = [rng.randint_scalar(k_pool, p, layout.pool_region)
            for p in range(spec.shared_pool_lines)]

    lines = np.full((i_n, w_n, l_n), -1, np.int32)
    pcs = np.zeros((i_n, w_n), np.int32)
    arch1_out = np.zeros((w_n,), np.int32)
    arch2_out = np.zeros((w_n,), np.int32)
    half_at = i_n // 2

    for wi in range(w_n):
        u = rng.uniform_scalar(k_arch, wi)
        arch1 = min(int(np.searchsorted(cum, u, side="right")), n_arch - 1)
        arch2 = arch1
        if spec.phase_shift:
            if rng.uniform_scalar(k_phase, wi) < spec.phase_flip_prob:
                arch2 = rng.randint_scalar(k_pick, wi, n_arch)
        arch1_out[wi], arch2_out[wi] = arch1, arch2

        wkey = rng.bits_scalar(k_ws, wi)
        ws_base = int(layout.ws_base(wi))
        ws = [ws_base + rng.perm12_scalar(j, wkey) for j in range(max_ws)]
        pcs_w = [rng.randint_scalar(k_pc, wi * spec.n_pcs + j, 1 << 16)
                 for j in range(spec.n_pcs)]
        params = {a: (int(tab[a, 0]), float(tab[a, 1]), float(tab[a, 2]))
                  for a in (arch1, arch2)}

        for ii in range(i_n):
            ws_size, reuse, shared = params[arch1 if ii < half_at else arch2]
            pcs[ii, wi] = pcs_w[ii % spec.n_pcs]
            for li in range(l_n):
                flat = (ii * w_n + wi) * l_n + li
                u = rng.uniform_scalar(k_reuse, flat)
                u2 = rng.uniform_scalar(k_shared_u, flat)
                if ws_size and u < reuse:
                    if shared and u2 < shared:
                        lines[ii, wi, li] = pool[rng.randint_scalar(
                            k_shared_idx, flat, spec.shared_pool_lines)]
                    else:
                        lines[ii, wi, li] = ws[rng.randint_scalar(
                            k_ws_idx, flat, max(ws_size, 1))]
                else:
                    lines[ii, wi, li] = layout.fresh_addr(wi, ii * l_n + li)

    return {
        "lines": lines,
        "pcs": pcs,
        "compute_gap": spec.compute_gap,
        "archetype": arch1_out,
        "archetype2": arch2_out,
    }
