"""Scheduler-stress scenario matrix — warp populations far beyond the
paper's 48, in the spirit of the larger sweeps of WaSP (arXiv:2404.06156)
and Dynamic Warp Resizing (arXiv:1208.2374).

Three stressor families, each isolating one pressure source:

  * HAMMER — queue-hammering: memory-bound intensity with a
    mostly-miss/all-miss-dominated mix, so nearly every instruction
    floods the L2 bank queues and the DRAM low-priority queue (Fig 5's
    tail, at 40-80x the request rate);
  * PHASE — phase-shift-heavy: most warps flip archetype mid-kernel,
    stressing the warp-type classifier's re-learning path (Fig 4's
    long-term-shift caveat made the common case);
  * FRONTIER — shared-pool-dominated graph frontiers: reuse is mostly
    inter-warp (boosted shared fractions, larger pool), so per-warp
    insertion/bypass decisions interact across the whole population.

All specs keep the paper's 64x16 instruction geometry so a trace at
n_warps=4096 stays ~16 MB and the full matrix generates in seconds on
the vectorized sampler (benchmarks/run.py --only tracegen).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.tracegen.spec import Phase, TraceSpec

_HAMMER_MIX: Tuple[float, ...] = (0.02, 0.08, 0.10, 0.45, 0.35)
_PHASE_MIX: Tuple[float, ...] = (0.10, 0.25, 0.30, 0.25, 0.10)
_FRONTIER_MIX: Tuple[float, ...] = (0.05, 0.25, 0.30, 0.25, 0.15)

STRESS_SPECS: Dict[str, TraceSpec] = {s.name: s for s in [
    TraceSpec("WIDE1K", mix=(0.05, 0.25, 0.10, 0.35, 0.25), intensity=0.95,
              n_warps=1024),
    TraceSpec("HAMMER2K", mix=_HAMMER_MIX, intensity=1.0, n_warps=2048),
    TraceSpec("HAMMER4K", mix=_HAMMER_MIX, intensity=0.98, n_warps=4096),
    TraceSpec("PHASE2K", mix=_PHASE_MIX, intensity=0.80, n_warps=2048,
              phase_shift=True, phase_flip_prob=0.75),
    TraceSpec("FRONTIER2K", mix=_FRONTIER_MIX, intensity=0.95, n_warps=2048,
              shared_boost=6.0, shared_pool_lines=512),
]}

STRESS_NAMES = tuple(STRESS_SPECS)

# ---------------------------------------------------------------------------
# Sharded-sweep stress tier (ISSUE 10): populations one to two orders
# beyond the 4k ceiling above, in the wide-warp spirit of the Dynamic
# Warp Resizing configs. Kept OUT of ``STRESS_SPECS`` so the default
# stress matrix (registry.STRESS, tier2-engine CI budgets) is unchanged
# — these sizes are meant for the wavefront engine's sharded-warp path
# on a device mesh (``Experiment(mesh=..., mesh_axes=(..., ..., axis))``;
# an 8-virtual-device CPU mesh suffices, see DESIGN.md §15). Both warp
# counts are powers of two so every 2^k-sized mesh axis divides them.
# ---------------------------------------------------------------------------

SHARD_STRESS_SPECS: Dict[str, TraceSpec] = {s.name: s for s in [
    TraceSpec("HAMMER16K", mix=_HAMMER_MIX, intensity=1.0, n_warps=16384),
    TraceSpec("WIDE64K", mix=(0.05, 0.25, 0.10, 0.35, 0.25),
              intensity=0.95, n_warps=65536),
]}

SHARD_STRESS_NAMES = tuple(SHARD_STRESS_SPECS)

# ---------------------------------------------------------------------------
# PHASED family (ISSUE 5): drifting-regime schedules for the online
# warp-reclassification story. Unlike PHASE2K (whose warps flip once at
# the midpoint), these specs swing the whole population's hit-ratio
# structure through distinct regimes — hit-heavy -> mixed -> miss-heavy,
# with working-set churn at the boundaries — so a phase-0 warp-type
# label is WRONG for most of the run and the classifier's
# reclassification window is what decides bypass/insertion/priority
# quality. Historical note: PR 5 restricted the family to this
# *degrading* direction because the classifier of that era could follow
# a warp down but not back up — bypassed requests counted as misses, so
# the 1-in-8 probe capped a bypassing warp's observable window hit
# ratio at 0.125 < the 0.2 mostly-miss threshold (the probe-ratchet).
# PR 7 fixed the ratchet (``classifier.observe`` measures the window
# ratio over the cache-path ``probed`` sample only, so a reformed
# warp's probe stream can cross the 0.8 mostly-hit threshold), which is
# what makes the PHASED_RECOVER_* mirror family below measurable at
# all. Sized 48 (differential-testable on the event engine) up to 2k
# warps (wavefront-only scale).
# ---------------------------------------------------------------------------

_HIT_HEAVY = (0.30, 0.45, 0.15, 0.07, 0.03)
_MIXED = (0.10, 0.25, 0.30, 0.25, 0.10)
_MISS_HEAVY = (0.03, 0.07, 0.15, 0.40, 0.35)

#: hit-heavy warm-up, slide to a mixed regime with working-set churn,
#: then a hard swing to miss-heavy at raised memory pressure — the
#: canonical degrading 3-regime drift schedule used at every PHASED_*
#: size
_DRIFT_SCHEDULE = (
    Phase(frac=1.0, mix=_HIT_HEAVY),
    Phase(frac=1.0, mix=_MIXED, churn=0.5),
    Phase(frac=1.0, mix=_MISS_HEAVY, churn=0.5, intensity=0.98),
)


def _phased(name: str, n_warps: int, intensity: float) -> TraceSpec:
    return TraceSpec(name, mix=_MIXED, intensity=intensity,
                     n_warps=n_warps, phases=_DRIFT_SCHEDULE)


PHASED_SPECS: Dict[str, TraceSpec] = {s.name: s for s in [
    _phased("PHASED48", 48, 0.95),
    _phased("PHASED256", 256, 0.95),
    _phased("PHASED1K", 1024, 0.92),
    _phased("PHASED2K", 2048, 0.90),
]}

PHASED_NAMES = tuple(PHASED_SPECS)

#: the mirror drift — miss-heavy warm-up at raised memory pressure,
#: slide back through mixed, then a hit-heavy tail. Phase-0 labels are
#: miss-shaped, so under a bypass policy the classifier must ratchet
#: labels back UP off the probe stream to stop bypassing reformed warps
#: — exactly the direction the pre-PR 7 probe-ratchet made impossible
#: (and PR 5 therefore had to avoid). Same 3-regime geometry as
#: ``_DRIFT_SCHEDULE`` so the two directions are comparable
#: like-for-like.
_RECOVER_SCHEDULE = (
    Phase(frac=1.0, mix=_MISS_HEAVY, churn=0.5, intensity=0.98),
    Phase(frac=1.0, mix=_MIXED, churn=0.5),
    Phase(frac=1.0, mix=_HIT_HEAVY),
)


def _phased_recover(name: str, n_warps: int, intensity: float) -> TraceSpec:
    return TraceSpec(name, mix=_MIXED, intensity=intensity,
                     n_warps=n_warps, phases=_RECOVER_SCHEDULE)


PHASED_RECOVER_SPECS: Dict[str, TraceSpec] = {s.name: s for s in [
    _phased_recover("PHASED_RECOVER48", 48, 0.95),
    _phased_recover("PHASED_RECOVER256", 256, 0.95),
    _phased_recover("PHASED_RECOVER1K", 1024, 0.92),
    _phased_recover("PHASED_RECOVER2K", 2048, 0.90),
]}

PHASED_RECOVER_NAMES = tuple(PHASED_RECOVER_SPECS)
