"""Scheduler-stress scenario matrix — warp populations far beyond the
paper's 48, in the spirit of the larger sweeps of WaSP (arXiv:2404.06156)
and Dynamic Warp Resizing (arXiv:1208.2374).

Three stressor families, each isolating one pressure source:

  * HAMMER — queue-hammering: memory-bound intensity with a
    mostly-miss/all-miss-dominated mix, so nearly every instruction
    floods the L2 bank queues and the DRAM low-priority queue (Fig 5's
    tail, at 40-80x the request rate);
  * PHASE — phase-shift-heavy: most warps flip archetype mid-kernel,
    stressing the warp-type classifier's re-learning path (Fig 4's
    long-term-shift caveat made the common case);
  * FRONTIER — shared-pool-dominated graph frontiers: reuse is mostly
    inter-warp (boosted shared fractions, larger pool), so per-warp
    insertion/bypass decisions interact across the whole population.

All specs keep the paper's 64x16 instruction geometry so a trace at
n_warps=4096 stays ~16 MB and the full matrix generates in seconds on
the vectorized sampler (benchmarks/run.py --only tracegen).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.core.tracegen.spec import TraceSpec

_HAMMER_MIX: Tuple[float, ...] = (0.02, 0.08, 0.10, 0.45, 0.35)
_PHASE_MIX: Tuple[float, ...] = (0.10, 0.25, 0.30, 0.25, 0.10)
_FRONTIER_MIX: Tuple[float, ...] = (0.05, 0.25, 0.30, 0.25, 0.15)

STRESS_SPECS: Dict[str, TraceSpec] = {s.name: s for s in [
    TraceSpec("WIDE1K", mix=(0.05, 0.25, 0.10, 0.35, 0.25), intensity=0.95,
              n_warps=1024),
    TraceSpec("HAMMER2K", mix=_HAMMER_MIX, intensity=1.0, n_warps=2048),
    TraceSpec("HAMMER4K", mix=_HAMMER_MIX, intensity=0.98, n_warps=4096),
    TraceSpec("PHASE2K", mix=_PHASE_MIX, intensity=0.80, n_warps=2048,
              phase_shift=True, phase_flip_prob=0.75),
    TraceSpec("FRONTIER2K", mix=_FRONTIER_MIX, intensity=0.95, n_warps=2048,
              shared_boost=6.0, shared_pool_lines=512),
]}

STRESS_NAMES = tuple(STRESS_SPECS)
