"""TraceSpec: archetype mixtures lowered to per-warp parameter arrays.

The lowering contract (DESIGN.md §"Trace generation"):

  spec  ──lower──►  (AddressLayout, WarpParams)  ──sample──►  lines/pcs

* ``AddressLayout`` partitions the int32 line-address space into three
  DISJOINT regions so the trace invariants are true by construction, at
  any warp count: the shared pool sits in [0, 2^13), warp ``w``'s private
  working set in [(w+1)<<13, (w+2)<<13), and the streaming (always-fresh)
  region above every working set. At the paper's scale (48 warps, 64
  instructions x 16 lanes) the layout constants reduce to the original
  generator's (fresh base 2^22, per-warp fresh stride 2^15).

* ``WarpParams`` holds, per seed and per warp: the archetype of each
  PHASE of the kernel, the lowered per-phase scalars (working-set size,
  reuse probability, shared fraction), the per-phase working-set line
  tables (a keyed 12-bit Feistel permutation — distinct lines without
  replacement), the PC table and the shared pool.

* The **phase schedule** (DESIGN.md §11). A spec without ``phases`` is
  the legacy model: two identical kernel halves, optionally connected by
  the ``phase_shift`` mid-kernel archetype flip (Fig 4) — lowered with
  exactly the seed-era RNG draws, so legacy traces are byte-identical.
  A spec WITH ``phases`` is a drifting workload: each ``Phase`` entry
  occupies ``frac`` of the instruction stream and may, at its entry
  boundary, redraw warp archetypes from a new ``mix`` (``flip_prob``
  controls what fraction of warps redraw), re-key private working sets
  (``churn`` — cold misses even for stable-type warps), and change
  ``intensity`` (lowered to a per-instruction compute gap). All phase
  draws are counter-RNG draws at (tag, p*W + w), so ``ref.py`` stays
  bit-identical to the vectorized sampler, and a single-phase schedule
  reduces byte-identically to the static legacy spec.

Everything downstream of ``lower`` is a pure function of these arrays,
which is what lets ``sampler.py`` materialize all cells at once.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core.tracegen import rng

# archetype = (working-set lines, reuse probability, shared-pool fraction)
# — the five warp types of Fig 2, spanning all-hit .. all-miss.
ARCHETYPES = {
    "all_hit": (16, 0.998, 0.0),
    "mostly_hit": (24, 0.96, 0.05),
    "balanced": (64, 0.50, 0.10),
    "mostly_miss": (128, 0.15, 0.10),
    "all_miss": (0, 0.0, 0.0),
}

WS_REGION_BITS = 13                   # 8192-line private region per warp
WS_CHOICE_BITS = 12                   # working set drawn from 4096 offsets
_MIN_FRESH_BASE = 1 << 22
_MIN_FRESH_STRIDE = 1 << 15
_INT32_LIMIT = (1 << 31) - 1


def _npow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


def _gap_of(intensity: float) -> np.float32:
    return np.float32(4.0 + (1.0 - intensity) * 120.0)


@dataclasses.dataclass(frozen=True)
class Phase:
    """One entry of a ``TraceSpec.phases`` schedule.

    frac:      relative length weight (normalized over the schedule and
               lowered to instruction boundaries);
    mix:       archetype mixture warps redraw from at phase entry
               (None: redraws — if any — use the spec's base mix);
    flip_prob: fraction of warps that redraw at phase entry; default is
               1.0 when ``mix`` is given (a real regime change) and 0.0
               otherwise (pure continuation). Ignored for phase 0, which
               always draws.
    churn:     probability a warp re-keys its private working set at
               phase entry (cold working-set misses). Ignored for
               phase 0 (its working set is always freshly keyed).
    intensity: per-phase intensity override (None: spec.intensity);
               lowered to a per-instruction compute gap.
    """
    frac: float = 1.0
    mix: Optional[Tuple[float, ...]] = None
    flip_prob: Optional[float] = None
    churn: float = 0.0
    intensity: Optional[float] = None

    def __post_init__(self):
        if self.mix is not None:
            object.__setattr__(self, "mix", tuple(float(m) for m in self.mix))


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Workload-agnostic trace description. ``mix`` gives the fraction of
    warps drawn from each archetype (same order as ``archetypes``)."""
    name: str
    mix: Tuple[float, ...]
    intensity: float                   # 1 = memory bound (tiny compute gap)
    n_warps: int = 48
    n_instr: int = 64
    lines_per_instr: int = 16
    n_pcs: int = 12
    phase_shift: bool = False          # mid-kernel archetype change
    phase_flip_prob: float = 0.25
    shared_pool_lines: int = 256
    shared_boost: float = 1.0          # multiplier on archetype shared fracs
    archetypes: Optional[Tuple[Tuple[int, float, float], ...]] = None
    phases: Optional[Tuple[Phase, ...]] = None   # drifting-regime schedule

    @classmethod
    def from_workload(cls, wl) -> "TraceSpec":
        """Lift a legacy ``workloads.WorkloadSpec`` (duck-typed)."""
        return cls(name=wl.name, mix=tuple(wl.mix), intensity=wl.intensity,
                   n_warps=wl.n_warps, n_instr=wl.n_instr,
                   lines_per_instr=wl.lines_per_instr, n_pcs=wl.n_pcs,
                   phase_shift=wl.phase_shift)

    def archetype_table(self) -> np.ndarray:
        """f64[A, 3] rows of (ws_lines, reuse_p, shared_frac)."""
        rows = self.archetypes or tuple(ARCHETYPES.values())
        tab = np.asarray(rows, np.float64)
        tab[:, 2] = np.clip(tab[:, 2] * self.shared_boost, 0.0, 1.0)
        return tab

    @property
    def compute_gap(self) -> np.float32:
        return _gap_of(self.intensity)


def trace_key(spec_name: str, seed: int) -> int:
    """Root key of one (workload, seed) trace — the same convention the
    original generator used for its ``default_rng`` seed."""
    return rng.mix64_scalar(
        (int(seed) + (zlib.crc32(spec_name.encode()) << 32))
        & ((1 << 64) - 1))


@dataclasses.dataclass(frozen=True)
class AddressLayout:
    """Disjoint int32 address regions; all fields are line addresses."""
    n_warps: int
    pool_region: int                   # shared pool ⊂ [0, pool_region)
    fresh_base: int                    # streaming region starts here
    fresh_stride: int                  # per-warp streaming sub-region

    def ws_base(self, w) -> np.ndarray:
        return (np.asarray(w, np.int64) + 1) << WS_REGION_BITS

    def fresh_addr(self, w, slot) -> np.ndarray:
        """Streaming address of flat slot (ii*L + li) of warp w. Slots are
        consumed positionally, so a cell's address never depends on how
        many earlier draws chose the streaming branch."""
        return (self.fresh_base
                + np.asarray(w, np.int64) * self.fresh_stride
                + np.asarray(slot, np.int64))


def _validate_phases(spec: TraceSpec) -> None:
    n_arch = len(spec.archetypes or ARCHETYPES)
    if spec.phase_shift:
        raise ValueError(
            f"{spec.name}: phases= and phase_shift=True are mutually "
            "exclusive — the legacy mid-kernel flip IS a two-phase "
            "schedule; express it as phases instead")
    if not spec.phases:
        raise ValueError(f"{spec.name}: phases must be a non-empty tuple")
    total = 0.0
    for i, ph in enumerate(spec.phases):
        if not isinstance(ph, Phase):
            raise ValueError(f"{spec.name}: phases[{i}] is not a Phase")
        if not np.isfinite(ph.frac) or ph.frac < 0:
            raise ValueError(f"{spec.name}: phases[{i}].frac must be >= 0")
        total += float(ph.frac)
        if ph.mix is not None:
            if len(ph.mix) != n_arch:
                raise ValueError(
                    f"{spec.name}: phases[{i}].mix has {len(ph.mix)} "
                    f"entries, archetype table has {n_arch}")
            s = float(np.sum(np.asarray(ph.mix, np.float64)))
            if abs(s - 1.0) > 1e-9:
                raise ValueError(
                    f"{spec.name}: phases[{i}].mix sums to {s}, not 1")
        if ph.flip_prob is not None and not 0.0 <= ph.flip_prob <= 1.0:
            raise ValueError(
                f"{spec.name}: phases[{i}].flip_prob outside [0, 1]")
        if not 0.0 <= ph.churn <= 1.0:
            raise ValueError(f"{spec.name}: phases[{i}].churn outside [0, 1]")
        if ph.intensity is not None and not 0.0 <= ph.intensity <= 1.0:
            raise ValueError(
                f"{spec.name}: phases[{i}].intensity outside [0, 1]")
    if total <= 0:
        raise ValueError(f"{spec.name}: phase fracs sum to 0")


def make_layout(spec: TraceSpec) -> AddressLayout:
    # spec validation lives here because both the sampler and the loop
    # reference lower through make_layout first
    mix_sum = float(np.sum(np.asarray(spec.mix, np.float64)))
    if abs(mix_sum - 1.0) > 1e-9:
        raise ValueError(f"{spec.name}: mix sums to {mix_sum}, not 1")
    if spec.phases is not None:
        _validate_phases(spec)
    tab = spec.archetype_table()
    if tab[:, 0].max() > (1 << WS_CHOICE_BITS):
        raise ValueError(
            f"{spec.name}: working-set size {int(tab[:, 0].max())} exceeds "
            f"the {1 << WS_CHOICE_BITS}-line per-warp choice domain "
            f"(perm12 is only a bijection on [0, 4096))")
    ws_top = (spec.n_warps + 1) << WS_REGION_BITS
    fresh_base = max(_MIN_FRESH_BASE, _npow2(ws_top))
    fresh_stride = max(_MIN_FRESH_STRIDE,
                       _npow2(spec.n_instr * spec.lines_per_instr))
    top = fresh_base + spec.n_warps * fresh_stride
    if top > _INT32_LIMIT:
        raise ValueError(
            f"{spec.name}: address space overflows int32 "
            f"(n_warps={spec.n_warps}, top={top}); shrink the scenario")
    return AddressLayout(spec.n_warps, 1 << WS_REGION_BITS,
                         fresh_base, fresh_stride)


# ---------------------------------------------------------------------------
# phase-schedule compilation (shared by sampler.py and ref.py)
# ---------------------------------------------------------------------------

class PhasePlan(NamedTuple):
    """One lowered phase: everything the RNG draws need.

    ``legacy`` marks the seed-era second kernel half, whose flip draws
    stay at index w (TAG_PHASE / uniform TAG_PHASE_PICK) for bytewise
    compatibility; scheduled phases draw at index p*W + w instead.
    """
    cum: np.ndarray          # f64[A] inverse-CDF table for redraws
    flip_prob: float         # fraction of warps redrawing at entry
    churn: float             # fraction of warps re-keying working sets
    gap: np.float32          # compute gap while this phase runs
    legacy: bool


def compile_schedule(spec: TraceSpec
                     ) -> Tuple[np.ndarray, Tuple[PhasePlan, ...]]:
    """Lower the spec's schedule to (bounds i64[P+1], per-phase plans).

    ``bounds[p] .. bounds[p+1]`` is phase p's instruction range. A spec
    without ``phases`` compiles to the legacy two-half schedule (identical
    halves unless ``phase_shift``); zero-length phases (after rounding
    fracs to instruction boundaries) are legal — their entry draws still
    happen, so archetype/working-set chains stay well-defined.
    """
    base_cum = np.cumsum(np.asarray(spec.mix, np.float64))
    if spec.phases is None:
        flip = float(spec.phase_flip_prob) if spec.phase_shift else 0.0
        gap = _gap_of(spec.intensity)
        bounds = np.asarray([0, spec.n_instr // 2, spec.n_instr], np.int64)
        return bounds, (PhasePlan(base_cum, 1.0, 1.0, gap, False),
                        PhasePlan(base_cum, flip, 0.0, gap, True))
    _validate_phases(spec)
    fracs = np.asarray([p.frac for p in spec.phases], np.float64)
    cumfrac = np.cumsum(fracs) / fracs.sum()
    bounds = np.concatenate([
        [0], np.round(cumfrac * spec.n_instr).astype(np.int64)])
    bounds = np.maximum.accumulate(bounds)
    bounds[-1] = spec.n_instr
    plans = []
    for p, ph in enumerate(spec.phases):
        cum = np.cumsum(np.asarray(ph.mix, np.float64)) \
            if ph.mix is not None else base_cum
        flip = ph.flip_prob if ph.flip_prob is not None \
            else (1.0 if ph.mix is not None else 0.0)
        gap = _gap_of(spec.intensity if ph.intensity is None
                      else ph.intensity)
        plans.append(PhasePlan(cum, float(flip), float(ph.churn), gap,
                               False))
    return bounds, tuple(plans)


def phase_of_instr(spec: TraceSpec) -> np.ndarray:
    """i64[I]: which phase each instruction belongs to."""
    bounds, _ = compile_schedule(spec)
    return np.searchsorted(bounds[1:-1], np.arange(spec.n_instr),
                           side="right").astype(np.int64)


def lowered_gap(spec: TraceSpec):
    """Per-instruction compute gap: a f32 scalar when the whole schedule
    runs at one intensity (the legacy contract — and what keeps a
    single-phase spec byte-identical to its static form), else f32[I]."""
    bounds, plans = compile_schedule(spec)
    gaps = np.asarray([pl.gap for pl in plans], np.float32)
    if np.all(gaps == gaps[0]):
        return gaps[0]
    return gaps[phase_of_instr(spec)]


@dataclasses.dataclass(frozen=True)
class WarpParams:
    """Per-(seed, warp, phase) lowered parameters. Leading axis
    S = len(seeds); P = number of schedule phases (2 for legacy specs)."""
    arch: np.ndarray         # i64[S, W, P] archetype per phase
    ws_size: np.ndarray      # i64[S, W, P] working-set lines per phase
    reuse: np.ndarray        # f64[S, W, P] reuse probability per phase
    shared: np.ndarray       # f64[S, W, P] shared fraction per phase
    ws_table: np.ndarray     # i64[S, W, P, max_ws] working-set line addrs
    pc_table: np.ndarray     # i32[S, W, n_pcs]
    pool: np.ndarray         # i64[S, P] shared-pool line addrs

    @property
    def n_phases(self) -> int:
        return self.arch.shape[-1]


def _inv_cdf(cum: np.ndarray, u: np.ndarray) -> np.ndarray:
    return np.minimum(np.searchsorted(cum, u, side="right"),
                      len(cum) - 1).astype(np.int64)


def lower(spec: TraceSpec, seeds) -> Tuple[AddressLayout, WarpParams]:
    """Lower the schedule to per-(warp, phase) parameter arrays for every
    seed in ``seeds`` at once (vectorized; the loop reference in ref.py
    recomputes the same values scalar-wise)."""
    seeds = np.atleast_1d(np.asarray(seeds, np.int64))
    layout = make_layout(spec)
    tab = spec.archetype_table()
    n_arch = tab.shape[0]
    w_n = spec.n_warps
    w_idx = np.arange(w_n, dtype=np.uint64)[None, :]              # [1, W]
    roots = np.asarray([trace_key(spec.name, int(s)) for s in seeds],
                       np.uint64)[:, None]                        # [S, 1]
    _, plans = compile_schedule(spec)

    # phase 0: archetype via inverse CDF; freshly keyed working set —
    # exactly the legacy per-warp draws
    arch_p = [_inv_cdf(plans[0].cum,
                       rng.uniform(rng.stream_key(roots, rng.TAG_ARCH),
                                   w_idx))]
    key_p = [rng.bits(rng.stream_key(roots, rng.TAG_WS), w_idx)]  # [S, W]

    for p, plan in enumerate(plans[1:], start=1):
        if plan.legacy:
            flip = rng.uniform(rng.stream_key(roots, rng.TAG_PHASE),
                               w_idx) < plan.flip_prob
            pick = rng.randint(rng.stream_key(roots, rng.TAG_PHASE_PICK),
                               w_idx, n_arch)
            arch_p.append(np.where(flip, pick, arch_p[-1]))
            key_p.append(key_p[-1])                # legacy never re-keys
            continue
        pidx = np.uint64(p) * np.uint64(w_n) + w_idx
        flip = rng.uniform(rng.stream_key(roots, rng.TAG_PHASE),
                           pidx) < plan.flip_prob
        pick = _inv_cdf(plan.cum,
                        rng.uniform(rng.stream_key(roots, rng.TAG_PHASE_MIX),
                                    pidx))
        arch_p.append(np.where(flip, pick, arch_p[-1]))
        rekey = rng.uniform(rng.stream_key(roots, rng.TAG_WS_CHURN),
                            pidx) < plan.churn
        key_p.append(np.where(
            rekey, rng.bits(rng.stream_key(roots, rng.TAG_WS_KEY), pidx),
            key_p[-1]))

    arch = np.stack(arch_p, axis=-1)                              # [S, W, P]
    wkeys = np.stack(key_p, axis=-1)                              # [S, W, P]
    ws_size = tab[arch, 0].astype(np.int64)
    reuse = tab[arch, 1]
    shared = tab[arch, 2]

    # working-set tables: keyed Feistel permutation => distinct lines
    max_ws = max(int(tab[:, 0].max()), 1)
    j = np.arange(max_ws, dtype=np.uint64)[None, None, None, :]
    ws_table = layout.ws_base(np.arange(w_n))[None, :, None, None] \
        + rng.perm12(j, wkeys[:, :, :, None])

    pc_flat = w_idx[:, :, None] * np.uint64(spec.n_pcs) \
        + np.arange(spec.n_pcs, dtype=np.uint64)[None, None, :]
    pc_table = rng.randint(rng.stream_key(roots[:, :, None], rng.TAG_PC),
                           pc_flat, 1 << 16).astype(np.int32)

    p_idx = np.arange(spec.shared_pool_lines, dtype=np.uint64)[None, :]
    pool = rng.randint(rng.stream_key(roots, rng.TAG_POOL), p_idx,
                       layout.pool_region)

    return layout, WarpParams(arch, ws_size, reuse, shared, ws_table,
                              pc_table, pool)
