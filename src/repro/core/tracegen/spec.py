"""TraceSpec: archetype mixtures lowered to per-warp parameter arrays.

The lowering contract (DESIGN.md §"Trace generation"):

  spec  ──lower──►  (AddressLayout, WarpParams)  ──sample──►  lines/pcs

* ``AddressLayout`` partitions the int32 line-address space into three
  DISJOINT regions so the trace invariants are true by construction, at
  any warp count: the shared pool sits in [0, 2^13), warp ``w``'s private
  working set in [(w+1)<<13, (w+2)<<13), and the streaming (always-fresh)
  region above every working set. At the paper's scale (48 warps, 64
  instructions x 16 lanes) the layout constants reduce to the original
  generator's (fresh base 2^22, per-warp fresh stride 2^15).

* ``WarpParams`` holds, per seed and per warp: the archetype for each
  kernel half (phase shifts flip archetypes at the midpoint, Fig 4), the
  lowered per-half scalars (working-set size, reuse probability, shared
  fraction), the working-set line table (a keyed 12-bit Feistel
  permutation — distinct lines without replacement), the PC table and
  the shared pool.

Everything downstream of ``lower`` is a pure function of these arrays,
which is what lets ``sampler.py`` materialize all cells at once.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Tuple

import numpy as np

from repro.core.tracegen import rng

# archetype = (working-set lines, reuse probability, shared-pool fraction)
# — the five warp types of Fig 2, spanning all-hit .. all-miss.
ARCHETYPES = {
    "all_hit": (16, 0.998, 0.0),
    "mostly_hit": (24, 0.96, 0.05),
    "balanced": (64, 0.50, 0.10),
    "mostly_miss": (128, 0.15, 0.10),
    "all_miss": (0, 0.0, 0.0),
}

WS_REGION_BITS = 13                   # 8192-line private region per warp
WS_CHOICE_BITS = 12                   # working set drawn from 4096 offsets
_MIN_FRESH_BASE = 1 << 22
_MIN_FRESH_STRIDE = 1 << 15
_INT32_LIMIT = (1 << 31) - 1


def _npow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Workload-agnostic trace description. ``mix`` gives the fraction of
    warps drawn from each archetype (same order as ``archetypes``)."""
    name: str
    mix: Tuple[float, ...]
    intensity: float                   # 1 = memory bound (tiny compute gap)
    n_warps: int = 48
    n_instr: int = 64
    lines_per_instr: int = 16
    n_pcs: int = 12
    phase_shift: bool = False          # mid-kernel archetype change
    phase_flip_prob: float = 0.25
    shared_pool_lines: int = 256
    shared_boost: float = 1.0          # multiplier on archetype shared fracs
    archetypes: Optional[Tuple[Tuple[int, float, float], ...]] = None

    @classmethod
    def from_workload(cls, wl) -> "TraceSpec":
        """Lift a legacy ``workloads.WorkloadSpec`` (duck-typed)."""
        return cls(name=wl.name, mix=tuple(wl.mix), intensity=wl.intensity,
                   n_warps=wl.n_warps, n_instr=wl.n_instr,
                   lines_per_instr=wl.lines_per_instr, n_pcs=wl.n_pcs,
                   phase_shift=wl.phase_shift)

    def archetype_table(self) -> np.ndarray:
        """f64[A, 3] rows of (ws_lines, reuse_p, shared_frac)."""
        rows = self.archetypes or tuple(ARCHETYPES.values())
        tab = np.asarray(rows, np.float64)
        tab[:, 2] = np.clip(tab[:, 2] * self.shared_boost, 0.0, 1.0)
        return tab

    @property
    def compute_gap(self) -> np.float32:
        return np.float32(4.0 + (1.0 - self.intensity) * 120.0)


def trace_key(spec_name: str, seed: int) -> int:
    """Root key of one (workload, seed) trace — the same convention the
    original generator used for its ``default_rng`` seed."""
    return rng.mix64_scalar(
        (int(seed) + (zlib.crc32(spec_name.encode()) << 32))
        & ((1 << 64) - 1))


@dataclasses.dataclass(frozen=True)
class AddressLayout:
    """Disjoint int32 address regions; all fields are line addresses."""
    n_warps: int
    pool_region: int                   # shared pool ⊂ [0, pool_region)
    fresh_base: int                    # streaming region starts here
    fresh_stride: int                  # per-warp streaming sub-region

    def ws_base(self, w) -> np.ndarray:
        return (np.asarray(w, np.int64) + 1) << WS_REGION_BITS

    def fresh_addr(self, w, slot) -> np.ndarray:
        """Streaming address of flat slot (ii*L + li) of warp w. Slots are
        consumed positionally, so a cell's address never depends on how
        many earlier draws chose the streaming branch."""
        return (self.fresh_base
                + np.asarray(w, np.int64) * self.fresh_stride
                + np.asarray(slot, np.int64))


def make_layout(spec: TraceSpec) -> AddressLayout:
    # spec validation lives here because both the sampler and the loop
    # reference lower through make_layout first
    mix_sum = float(np.sum(np.asarray(spec.mix, np.float64)))
    if abs(mix_sum - 1.0) > 1e-9:
        raise ValueError(f"{spec.name}: mix sums to {mix_sum}, not 1")
    tab = spec.archetype_table()
    if tab[:, 0].max() > (1 << WS_CHOICE_BITS):
        raise ValueError(
            f"{spec.name}: working-set size {int(tab[:, 0].max())} exceeds "
            f"the {1 << WS_CHOICE_BITS}-line per-warp choice domain "
            f"(perm12 is only a bijection on [0, 4096))")
    ws_top = (spec.n_warps + 1) << WS_REGION_BITS
    fresh_base = max(_MIN_FRESH_BASE, _npow2(ws_top))
    fresh_stride = max(_MIN_FRESH_STRIDE,
                       _npow2(spec.n_instr * spec.lines_per_instr))
    top = fresh_base + spec.n_warps * fresh_stride
    if top > _INT32_LIMIT:
        raise ValueError(
            f"{spec.name}: address space overflows int32 "
            f"(n_warps={spec.n_warps}, top={top}); shrink the scenario")
    return AddressLayout(spec.n_warps, 1 << WS_REGION_BITS,
                         fresh_base, fresh_stride)


@dataclasses.dataclass(frozen=True)
class WarpParams:
    """Per-(seed, warp) lowered parameters. Leading axis S = len(seeds)."""
    arch1: np.ndarray        # i64[S, W] archetype, first kernel half
    arch2: np.ndarray        # i64[S, W] archetype, second half
    ws_size: np.ndarray      # i64[S, W, 2] working-set lines per half
    reuse: np.ndarray        # f64[S, W, 2] reuse probability per half
    shared: np.ndarray       # f64[S, W, 2] shared fraction per half
    ws_table: np.ndarray     # i64[S, W, max_ws] working-set line addrs
    pc_table: np.ndarray     # i32[S, W, n_pcs]
    pool: np.ndarray         # i64[S, P] shared-pool line addrs


def lower(spec: TraceSpec, seeds) -> Tuple[AddressLayout, WarpParams]:
    """Lower the archetype mixture to per-warp parameter arrays for every
    seed in ``seeds`` at once (vectorized; the loop reference in ref.py
    recomputes the same values scalar-wise)."""
    seeds = np.atleast_1d(np.asarray(seeds, np.int64))
    layout = make_layout(spec)
    tab = spec.archetype_table()
    n_arch = tab.shape[0]
    w_idx = np.arange(spec.n_warps, dtype=np.uint64)[None, :]     # [1, W]
    roots = np.asarray([trace_key(spec.name, int(s)) for s in seeds],
                       np.uint64)[:, None]                        # [S, 1]

    # archetype mixture -> per-warp archetype via inverse CDF
    cum = np.cumsum(np.asarray(spec.mix, np.float64))
    u = rng.uniform(rng.stream_key(roots, rng.TAG_ARCH), w_idx)
    arch1 = np.minimum(np.searchsorted(cum, u, side="right"),
                       n_arch - 1).astype(np.int64)
    if spec.phase_shift:
        flip = rng.uniform(rng.stream_key(roots, rng.TAG_PHASE),
                           w_idx) < spec.phase_flip_prob
        pick = rng.randint(rng.stream_key(roots, rng.TAG_PHASE_PICK),
                           w_idx, n_arch)
        arch2 = np.where(flip, pick, arch1)
    else:
        arch2 = arch1

    halves = np.stack([arch1, arch2], axis=-1)                    # [S, W, 2]
    ws_size = tab[halves, 0].astype(np.int64)
    reuse = tab[halves, 1]
    shared = tab[halves, 2]

    # working-set tables: keyed Feistel permutation => distinct lines
    max_ws = max(int(tab[:, 0].max()), 1)
    wkey = rng.bits(rng.stream_key(roots, rng.TAG_WS), w_idx)     # [S, W]
    j = np.arange(max_ws, dtype=np.uint64)[None, None, :]
    ws_table = layout.ws_base(np.arange(spec.n_warps))[None, :, None] \
        + rng.perm12(j, wkey[:, :, None])

    pc_flat = w_idx[:, :, None] * np.uint64(spec.n_pcs) \
        + np.arange(spec.n_pcs, dtype=np.uint64)[None, None, :]
    pc_table = rng.randint(rng.stream_key(roots[:, :, None], rng.TAG_PC),
                           pc_flat, 1 << 16).astype(np.int32)

    p_idx = np.arange(spec.shared_pool_lines, dtype=np.uint64)[None, :]
    pool = rng.randint(rng.stream_key(roots, rng.TAG_POOL), p_idx,
                       layout.pool_region)

    return layout, WarpParams(arch1, arch2, ws_size, reuse, shared,
                              ws_table, pc_table, pool)
