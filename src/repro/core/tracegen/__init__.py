"""Vectorized trace-generation subsystem (ISSUE 2 tentpole).

Replaces the triple-nested Python loop in the original
``repro.core.workloads.generate`` with a counter-based design:

  * ``spec.py``   — ``TraceSpec`` + ``lower()``: archetype mixtures are
    lowered to per-warp parameter arrays (working-set sizes, reuse and
    shared-pool probabilities per kernel half, working-set tables, PC
    tables) and a disjoint address-space layout;
  * ``rng.py``    — splitmix64-style counter RNG: every random draw is a
    pure function of ``(key, tag, index)``, so the loop reference and the
    vectorized sampler agree bit-for-bit;
  * ``sampler.py``— pure-numpy batched sampler materializing ``lines``
    and ``pcs`` for all I×W×L cells (and all seeds / specs) at once, plus
    ``generate_batch`` whose stacked output feeds ``simulate_sweep``;
  * ``ref.py``    — the legacy-shaped loop generator (per warp, per
    instruction, per lane) kept as the exact-parity reference;
  * ``stress.py`` — scheduler-stress scenario matrix with warp counts in
    the thousands (queue-hammering, phase-shift-heavy, shared-pool-
    dominated frontiers).

See DESIGN.md §"Trace generation" for the lowering contract.
"""
from repro.core.tracegen.ref import generate_ref
from repro.core.tracegen.sampler import generate, generate_batch
from repro.core.tracegen.spec import (ARCHETYPES, AddressLayout, Phase,
                                      TraceSpec, WarpParams,
                                      compile_schedule, lower, lowered_gap,
                                      phase_of_instr, trace_key)
from repro.core.tracegen.stress import (PHASED_RECOVER_SPECS, PHASED_SPECS,
                                        SHARD_STRESS_SPECS, STRESS_SPECS)

__all__ = [
    "ARCHETYPES", "AddressLayout", "Phase", "TraceSpec", "WarpParams",
    "compile_schedule", "lower", "lowered_gap", "phase_of_instr",
    "trace_key", "generate", "generate_batch", "generate_ref",
    "PHASED_RECOVER_SPECS", "PHASED_SPECS", "SHARD_STRESS_SPECS",
    "STRESS_SPECS",
]
