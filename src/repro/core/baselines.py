"""Policy presets: MeDiC, its three components, and the four comparison
mechanisms from the paper's evaluation (§5, Fig 7) — plus the labeling
ablation presets the phased scenario family compares (ISSUE 5)."""
from __future__ import annotations

import dataclasses

from repro.policy import Policy

BASELINE = Policy("Baseline")                                     # LRU, FR-FCFS
EAF = Policy("EAF", insertion="eaf")                              # [123]
PCAL = Policy("PCAL", bypass="pcal")                              # [79]
PC_BYP = Policy("PC-Byp", bypass="pcbyp")
WIP = Policy("WIP", insertion="medic")                            # ③ alone
WMS = Policy("WMS", scheduler="medic")                            # ④ alone
WBYP = Policy("WByp", bypass="medic")                             # ② alone
MEDIC = Policy("MeDiC", bypass="medic", insertion="medic",
               scheduler="medic")                                 # ②+③+④


def rand(p: float) -> Policy:
    return Policy(f"Rand({p:.2f})", bypass="rand", rand_p=p)


RAND_SWEEP = tuple(rand(p) for p in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8))

ALL_NAMED = (BASELINE, EAF, PCAL, PC_BYP, WIP, WMS, WBYP, MEDIC)


def with_labeling(pol: Policy, labeling: str, name: str = None,
                  reclass_interval: int = 0) -> Policy:
    """Labeling-mode ablation of a preset (① — online / stale / oracle),
    optionally with a non-default reclassification window."""
    return dataclasses.replace(
        pol, name=name or f"{pol.name}[{labeling}]", labeling=labeling,
        reclass_interval=reclass_interval)


# the phased-family labeling ladder: how much of MeDiC's win survives
# when labels freeze at phase 0 (stale), vs the paper's periodic
# reclassification (online, at the default and at a halved sampling
# window — the policy-visible reclassification knob), vs ground-truth
# per-phase labels (oracle)
MEDIC_STALE = with_labeling(MEDIC, "stale", "MeDiC-stale")
MEDIC_FAST = with_labeling(MEDIC, "online", "MeDiC-fast",
                           reclass_interval=32)
MEDIC_ORACLE = with_labeling(MEDIC, "oracle", "MeDiC-oracle")
LABELING_LADDER = (BASELINE, MEDIC_STALE, MEDIC, MEDIC_FAST, MEDIC_ORACLE)
