"""Policy presets: MeDiC, its three components, and the four comparison
mechanisms from the paper's evaluation (§5, Fig 7)."""
from __future__ import annotations

from repro.policy import Policy

BASELINE = Policy("Baseline")                                     # LRU, FR-FCFS
EAF = Policy("EAF", insertion="eaf")                              # [123]
PCAL = Policy("PCAL", bypass="pcal")                              # [79]
PC_BYP = Policy("PC-Byp", bypass="pcbyp")
WIP = Policy("WIP", insertion="medic")                            # ③ alone
WMS = Policy("WMS", scheduler="medic")                            # ④ alone
WBYP = Policy("WByp", bypass="medic")                             # ② alone
MEDIC = Policy("MeDiC", bypass="medic", insertion="medic",
               scheduler="medic")                                 # ②+③+④


def rand(p: float) -> Policy:
    return Policy(f"Rand({p:.2f})", bypass="rand", rand_p=p)


RAND_SWEEP = tuple(rand(p) for p in (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8))

ALL_NAMED = (BASELINE, EAF, PCAL, PC_BYP, WIP, WMS, WBYP, MEDIC)
