"""Deterministic synthetic LM data pipeline.

Produces a *learnable* token stream (noisy affine Markov chain over the
vocabulary) so end-to-end training demonstrably reduces loss. Fully
deterministic in (seed, step) — the iterator is checkpointable by storing a
single integer, and restart-resume yields bit-identical batches.

Sharding: ``get_batch`` returns the host's slice of the global batch
(``process_index``/``process_count`` API mirrors multi-host jax; this
container is single-process).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_noise: float = 0.15   # fraction of uniformly random tokens
    n_chains: int = 8            # distinct affine chains (mixture)


class SyntheticLM:
    def __init__(self, cfg: DataConfig, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count
        v = cfg.vocab_size
        chain_rng = np.random.default_rng(cfg.seed)
        # affine maps next = (a * prev + c) % V, co-prime multipliers
        self._a = chain_rng.choice(np.arange(3, 1000, 2), cfg.n_chains)
        self._c = chain_rng.integers(1, v, cfg.n_chains)

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        v = cfg.vocab_size
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + self.process_index)
        b, s = self.local_batch, cfg.seq_len
        chain = rng.integers(0, cfg.n_chains, b)
        a = self._a[chain][:, None]
        c = self._c[chain][:, None]
        toks = np.empty((b, s), np.int64)
        toks[:, 0] = rng.integers(0, v, b)
        noise = rng.random((b, s)) < cfg.markov_noise
        rand_toks = rng.integers(0, v, (b, s))
        for t in range(1, s):
            nxt = (a[:, 0] * toks[:, t - 1] + c[:, 0]) % v
            toks[:, t] = np.where(noise[:, t], rand_toks[:, t], nxt)
        return {"tokens": toks.astype(np.int32)}

    # -- checkpointable iterator ---------------------------------------------

    def iterator(self, start_step: int = 0) -> "CheckpointableIterator":
        return CheckpointableIterator(self, start_step)


class CheckpointableIterator:
    def __init__(self, ds: SyntheticLM, step: int = 0):
        self.ds = ds
        self.step = step

    def __next__(self):
        batch = self.ds.get_batch(self.step)
        self.step += 1
        return batch

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, state: Dict[str, int]):
        self.step = int(state["step"])
